//! Tiny flag parser: `--key value` pairs after a positional command.
//! The `snapshot` command additionally takes leading positional
//! operands (`edc snapshot info <file>`, `edc snapshot convert <in>
//! <out>`) before its flags; every other command stays flags-only.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Commands whose leading non-flag tokens are positional operands.
const POSITIONAL_COMMANDS: &[&str] = &["snapshot"];

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positionals: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("missing command");
        }
        let command = argv[0].clone();
        if command.starts_with('-') {
            bail!("expected a command first, got flag '{command}'");
        }
        let mut positionals = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 1;
        if POSITIONAL_COMMANDS.contains(&command.as_str()) {
            while i < argv.len() && !argv[i].starts_with("--") {
                positionals.push(argv[i].clone());
                i += 1;
            }
        }
        while i < argv.len() {
            let key = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{}'", argv[i]))?;
            let val = argv
                .get(i + 1)
                .ok_or_else(|| anyhow!("flag --{key} missing a value"))?;
            if val.starts_with("--") {
                bail!("flag --{key} missing a value (got '{val}')");
            }
            flags.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Args {
            command,
            positionals,
            flags,
        })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} wants an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} wants an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} wants a number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = Args::parse(&s(&["table", "--id", "4", "--seed", "7"])).unwrap();
        assert_eq!(a.command, "table");
        assert_eq!(a.get("id"), Some("4"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert_eq!(a.usize_or("episodes", 40).unwrap(), 40);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(&s(&[])).is_err());
        assert!(Args::parse(&s(&["--id", "4"])).is_err());
        assert!(Args::parse(&s(&["table", "--id"])).is_err());
        assert!(Args::parse(&s(&["table", "--id", "--seed"])).is_err());
        assert!(Args::parse(&s(&["table", "id", "4"])).is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let a = Args::parse(&s(&["cost", "--q", "abc"])).unwrap();
        assert!(a.f64_or("q", 8.0).is_err());
    }

    #[test]
    fn snapshot_command_takes_positionals_before_flags() {
        let a = Args::parse(&s(&["snapshot", "convert", "a.json", "b.edc4", "--to", "binary"]))
            .unwrap();
        assert_eq!(a.command, "snapshot");
        assert_eq!(a.positionals, vec!["convert", "a.json", "b.edc4"]);
        assert_eq!(a.get("to"), Some("binary"));
        // Other commands still refuse bare positionals.
        assert!(Args::parse(&s(&["table", "id", "4"])).is_err());
        // Flags still demand values after the positionals.
        assert!(Args::parse(&s(&["snapshot", "info", "a.json", "--to"])).is_err());
    }
}
