//! Dense f32 tensors.
//!
//! This is the numeric substrate for the Rust-side neural networks (the
//! SAC agent's MLPs) and for marshalling model weights between the
//! coordinator and the PJRT runtime. It deliberately supports exactly what
//! this project needs — row-major storage, 2-D GEMM variants with a
//! blocked inner loop, and elementwise ops — rather than being a general
//! ndarray clone.

use crate::util::rng::Rng;
use std::fmt;

/// Row-major dense f32 tensor with arbitrary rank.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{} elems]", self.data.len())
        }
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with {} elements",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Gaussian init with the given std (e.g. He/Xavier computed by caller).
    pub fn randn(shape: &[usize], std: f64, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal_f32(&mut t.data, std);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-matrix {:?}", self.shape);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on non-matrix {:?}", self.shape);
        self.shape[1]
    }

    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols() + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.cols();
        self.data[r * cols + c] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// self += alpha * other (axpy).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// self = self * a + other * b (used by soft target updates).
    pub fn lerp_into(&mut self, a: f32, other: &Tensor, b: f32) {
        assert_eq!(self.shape, other.shape, "lerp shape mismatch");
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x = *x * a + *y * b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Elementwise product into a new tensor.
    pub fn hadamard(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "hadamard shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a * b)
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    /// Squared L2 norm (f64 accumulation).
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Max |x|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// C = A @ B. Blocked i-k-j loop order — the k-j inner pair is
    /// auto-vectorizable and cache-friendly for row-major data.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul inner dim {k} vs {kb}");
        let mut c = Tensor::zeros(&[m, n]);
        matmul_into(&self.data, &b.data, &mut c.data, m, k, n);
        c
    }

    /// C = Aᵀ @ B where self is A (shape [k, m]). Avoids materializing Aᵀ.
    pub fn matmul_tn(&self, b: &Tensor) -> Tensor {
        let (k, m) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul_tn inner dim {k} vs {kb}");
        let mut c = Tensor::zeros(&[m, n]);
        // C[i,j] += A[p,i] * B[p,j]: loop p outer, rank-1 update with a
        // bounds-check-free zip (§Perf).
        for p in 0..k {
            let arow = &self.data[p * m..(p + 1) * m];
            let brow = &b.data[p * n..(p + 1) * n];
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for (cj, &bj) in crow.iter_mut().zip(brow) {
                    *cj += a * bj;
                }
            }
        }
        c
    }

    /// C = A @ Bᵀ where other is B (shape [n, k]). Dot-product form.
    pub fn matmul_nt(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, kb) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul_nt inner dim {k} vs {kb}");
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                // 4 independent accumulators break the FP dependency
                // chain so the dot product vectorizes (§Perf).
                let mut acc = [0.0f32; 4];
                let (ach, art) = arow.split_at(k - k % 4);
                let (bch, brt) = brow.split_at(k - k % 4);
                for (av, bv) in ach.chunks_exact(4).zip(bch.chunks_exact(4)) {
                    acc[0] += av[0] * bv[0];
                    acc[1] += av[1] * bv[1];
                    acc[2] += av[2] * bv[2];
                    acc[3] += av[3] * bv[3];
                }
                let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
                for (av, bv) in art.iter().zip(brt) {
                    s += av * bv;
                }
                c.data[i * n + j] = s;
            }
        }
        c
    }

    /// Broadcast-add a row vector [1, n] to each row of [m, n].
    pub fn add_row(&self, row: &Tensor) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        assert_eq!(row.len(), n, "add_row len mismatch");
        let mut out = self.clone();
        for i in 0..m {
            for j in 0..n {
                out.data[i * n + j] += row.data[j];
            }
        }
        out
    }

    /// Column-wise sum producing [1, n] — the bias gradient.
    pub fn sum_rows(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[1, n]);
        for i in 0..m {
            for j in 0..n {
                out.data[j] += self.data[i * n + j];
            }
        }
        out
    }

    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }
}

/// Blocked GEMM kernel: C += A[m,k] @ B[k,n]. Exposed so the perf pass can
/// bench it directly.
///
/// Perf notes (EXPERIMENTS.md §Perf): i-k-j loop order with a 2-way
/// unrolled k so two B rows stream per C-row pass; the j loop is a
/// bounds-check-free `zip` that LLVM auto-vectorizes. ~3.5x over the
/// naive blocked version at SAC's 64x166x128 shape.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    const BK: usize = 128;
    for k0 in (0..k).step_by(BK) {
        let kend = (k0 + BK).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut p = k0;
            // 2-way k-unroll: one pass over crow applies two rank-1 rows.
            while p + 1 < kend {
                let a0 = arow[p];
                let a1 = arow[p + 1];
                if a0 == 0.0 && a1 == 0.0 {
                    p += 2;
                    continue;
                }
                let b0 = &b[p * n..p * n + n];
                let b1 = &b[(p + 1) * n..(p + 1) * n + n];
                for ((cj, &x0), &x1) in crow.iter_mut().zip(b0).zip(b1) {
                    *cj += a0 * x0 + a1 * x1;
                }
                p += 2;
            }
            if p < kend {
                let a0 = arow[p];
                if a0 != 0.0 {
                    let b0 = &b[p * n..p * n + n];
                    for (cj, &x0) in crow.iter_mut().zip(b0) {
                        *cj += a0 * x0;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (16, 7, 9), (33, 65, 17)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            for (x, y) in c.data().iter().zip(c0.data()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let mut rng = Rng::new(12);
        let a = Tensor::randn(&[6, 4], 1.0, &mut rng); // A is [k=6, m=4]
        let b = Tensor::randn(&[6, 5], 1.0, &mut rng);
        let c = a.matmul_tn(&b);
        let c0 = a.transpose().matmul(&b);
        for (x, y) in c.data().iter().zip(c0.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let mut rng = Rng::new(13);
        let a = Tensor::randn(&[3, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 7], 1.0, &mut rng); // B is [n=5, k=7]
        let c = a.matmul_nt(&b);
        let c0 = a.matmul(&b.transpose());
        for (x, y) in c.data().iter().zip(c0.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn add_row_and_sum_rows_are_adjoint() {
        // <x + row, y> gradient wrt row is sum_rows(y): spot-check shapes/values.
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let row = Tensor::from_vec(&[1, 3], vec![10., 20., 30.]);
        let y = x.add_row(&row);
        assert_eq!(y.data(), &[11., 22., 33., 14., 25., 36.]);
        let s = y.sum_rows();
        assert_eq!(s.data(), &[25., 47., 69.]);
    }

    #[test]
    fn axpy_and_lerp() {
        let mut a = Tensor::from_vec(&[2], vec![1., 2.]);
        let b = Tensor::from_vec(&[2], vec![10., 10.]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6., 7.]);
        a.lerp_into(0.0, &b, 1.0);
        assert_eq!(a.data(), &[10., 10.]);
    }

    #[test]
    fn reshape_checks_size() {
        let t = Tensor::zeros(&[2, 3]);
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    #[should_panic]
    fn reshape_wrong_size_panics() {
        let t = Tensor::zeros(&[2, 3]);
        let _ = t.reshape(&[4, 2]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(&[2, 2], vec![1., -2., 3., -4.]);
        assert_eq!(t.sum(), -2.0);
        assert_eq!(t.mean(), -0.5);
        assert_eq!(t.abs_max(), 4.0);
        assert!((t.sq_norm() - 30.0).abs() < 1e-9);
    }
}
