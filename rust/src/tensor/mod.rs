//! Dense f32 tensors.
//!
//! This is the numeric substrate for the Rust-side neural networks (the
//! SAC agent's MLPs) and for marshalling model weights between the
//! coordinator and the PJRT runtime. It deliberately supports exactly what
//! this project needs — row-major storage, 2-D GEMM variants with a
//! blocked inner loop, and elementwise ops — rather than being a general
//! ndarray clone.
//!
//! # Allocating vs `*_into` paths
//!
//! Every GEMM / broadcast op exists in two forms: the original allocating
//! form (`matmul`, `matmul_tn`, ..., returning a fresh [`Tensor`]) and a
//! workspace form (`matmul_into`, `matmul_tn_into`, ...) that writes into
//! a caller-owned tensor. The `*_into` kernels are free to re-tile their
//! loops for locality, but they apply **the same floating-point operations
//! in the same order to every output element** as their allocating
//! counterpart, so for finite inputs the results are bit-identical (the
//! allocating kernels skip zero multipliers, which only differs from an
//! unconditional `+= 0.0*x` when `x` is non-finite). The SAC training loop
//! depends on this: search episode streams and checkpoints must not move
//! when the zero-allocation path is used (`rust/tests/prop_train.rs`).

#![deny(clippy::redundant_clone)]

use crate::util::rng::Rng;
use std::fmt;

/// Row-major dense f32 tensor with arbitrary rank.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{} elems]", self.data.len())
        }
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with {} elements",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Gaussian init with the given std (e.g. He/Xavier computed by caller).
    pub fn randn(shape: &[usize], std: f64, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal_f32(&mut t.data, std);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-matrix {:?}", self.shape);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on non-matrix {:?}", self.shape);
        self.shape[1]
    }

    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols() + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.cols();
        self.data[r * cols + c] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// self += alpha * other (axpy).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// self = self * a + other * b (used by soft target updates).
    pub fn lerp_into(&mut self, a: f32, other: &Tensor, b: f32) {
        assert_eq!(self.shape, other.shape, "lerp shape mismatch");
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x = *x * a + *y * b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Elementwise product into a new tensor.
    pub fn hadamard(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape, "hadamard shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a * b)
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    /// Squared L2 norm (f64 accumulation).
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Max |x|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// C = A @ B. Blocked i-k-j loop order — the k-j inner pair is
    /// auto-vectorizable and cache-friendly for row-major data.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul inner dim {k} vs {kb}");
        let mut c = Tensor::zeros(&[m, n]);
        matmul_into(&self.data, &b.data, &mut c.data, m, k, n);
        c
    }

    /// C = Aᵀ @ B where self is A (shape [k, m]). Avoids materializing Aᵀ.
    pub fn matmul_tn(&self, b: &Tensor) -> Tensor {
        let (k, m) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul_tn inner dim {k} vs {kb}");
        let mut c = Tensor::zeros(&[m, n]);
        // C[i,j] += A[p,i] * B[p,j]: loop p outer, rank-1 update with a
        // bounds-check-free zip (§Perf).
        for p in 0..k {
            let arow = &self.data[p * m..(p + 1) * m];
            let brow = &b.data[p * n..(p + 1) * n];
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for (cj, &bj) in crow.iter_mut().zip(brow) {
                    *cj += a * bj;
                }
            }
        }
        c
    }

    /// C = A @ Bᵀ where other is B (shape [n, k]). Dot-product form.
    pub fn matmul_nt(&self, b: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, kb) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul_nt inner dim {k} vs {kb}");
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                // 4 independent accumulators break the FP dependency
                // chain so the dot product vectorizes (§Perf).
                c.data[i * n + j] = dot4(arow, &b.data[j * k..(j + 1) * k]);
            }
        }
        c
    }

    /// Broadcast-add a row vector [1, n] to each row of [m, n].
    pub fn add_row(&self, row: &Tensor) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        assert_eq!(row.len(), n, "add_row len mismatch");
        let mut out = self.clone();
        for i in 0..m {
            for j in 0..n {
                out.data[i * n + j] += row.data[j];
            }
        }
        out
    }

    /// Column-wise sum producing [1, n] — the bias gradient.
    pub fn sum_rows(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[1, n]);
        for i in 0..m {
            for j in 0..n {
                out.data[j] += self.data[i * n + j];
            }
        }
        out
    }

    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        self.transpose_into(&mut out);
        out
    }

    // ---- workspace (`*_into`) variants: no allocation, bit-identical ----

    /// Overwrite `self` with `src` (shapes must match exactly).
    pub fn copy_from(&mut self, src: &Tensor) {
        assert_eq!(self.shape, src.shape, "copy_from shape mismatch");
        self.data.copy_from_slice(&src.data);
    }

    /// `out = self @ b` into a caller-owned `[m, n]` tensor, fully
    /// overwritten. Bit-identical to [`Tensor::matmul`] for finite inputs;
    /// uses a 4-row register block on top of the same k-pairing (see
    /// [`matmul_into_rows4`]).
    pub fn matmul_into(&self, b: &Tensor, out: &mut Tensor) {
        let (m, k) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul inner dim {k} vs {kb}");
        assert_eq!(out.shape(), &[m, n], "matmul_into out shape");
        out.data.fill(0.0);
        matmul_into_rows4(&self.data, &b.data, &mut out.data, m, k, n);
    }

    /// `out = selfᵀ @ b` where self is `[k, m]`, fully overwriting the
    /// caller-owned `[m, n]` output. Bit-identical to
    /// [`Tensor::matmul_tn`]: per element the rank-1 updates accumulate in
    /// the same ascending-`p` order with the same zero-skip; the loop is
    /// tiled over output columns so the C tile stays L1-resident instead
    /// of streaming the whole output once per `p` (the allocating kernel's
    /// memory-traffic bottleneck at SAC's `dw = xᵀ @ dy` shapes).
    pub fn matmul_tn_into(&self, b: &Tensor, out: &mut Tensor) {
        let (k, m) = (self.rows(), self.cols());
        let (kb, n) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul_tn inner dim {k} vs {kb}");
        assert_eq!(out.shape(), &[m, n], "matmul_tn_into out shape");
        out.data.fill(0.0);
        const BJ: usize = 32;
        let c = &mut out.data;
        for j0 in (0..n).step_by(BJ) {
            let jend = (j0 + BJ).min(n);
            for p in 0..k {
                let arow = &self.data[p * m..(p + 1) * m];
                let brow = &b.data[p * n + j0..p * n + jend];
                for (i, &a) in arow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let crow = &mut c[i * n + j0..i * n + jend];
                    for (cj, &bj) in crow.iter_mut().zip(brow) {
                        *cj += a * bj;
                    }
                }
            }
        }
    }

    /// `out = self @ bᵀ` where `b` is `[n, k]`, fully overwriting the
    /// caller-owned `[m, n]` output. Bit-identical to
    /// [`Tensor::matmul_nt`]: each output element is the same
    /// 4-accumulator dot product (`dot4`); the loop is tiled over B rows
    /// so a small block of B stays cache-hot across all of A instead of
    /// streaming the full B matrix once per A row.
    pub fn matmul_nt_into(&self, b: &Tensor, out: &mut Tensor) {
        let (m, k) = (self.rows(), self.cols());
        let (n, kb) = (b.rows(), b.cols());
        assert_eq!(k, kb, "matmul_nt inner dim {k} vs {kb}");
        assert_eq!(out.shape(), &[m, n], "matmul_nt_into out shape");
        const BJ: usize = 8;
        for j0 in (0..n).step_by(BJ) {
            let jend = (j0 + BJ).min(n);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                for j in j0..jend {
                    let brow = &b.data[j * k..(j + 1) * k];
                    out.data[i * n + j] = dot4(arow, brow);
                }
            }
        }
    }

    /// Transpose into a caller-owned `[n, m]` tensor.
    pub fn transpose_into(&self, out: &mut Tensor) {
        let (m, n) = (self.rows(), self.cols());
        assert_eq!(out.shape(), &[n, m], "transpose_into out shape");
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
    }

    /// In-place broadcast-add of a row vector `[1, n]` to each row of
    /// `self` — the workspace form of [`Tensor::add_row`] (same
    /// element-wise additions, no clone).
    pub fn add_row_into(&mut self, row: &Tensor) {
        let (m, n) = (self.rows(), self.cols());
        assert_eq!(row.len(), n, "add_row len mismatch");
        for i in 0..m {
            let r = &mut self.data[i * n..(i + 1) * n];
            for (v, &x) in r.iter_mut().zip(&row.data) {
                *v += x;
            }
        }
    }

    /// Column-wise sum into a caller-owned `[1, n]` tensor — the workspace
    /// form of [`Tensor::sum_rows`] (same row-major accumulation order).
    pub fn sum_rows_into(&self, out: &mut Tensor) {
        let (m, n) = (self.rows(), self.cols());
        assert_eq!(out.shape(), &[1, n], "sum_rows_into out shape");
        out.data.fill(0.0);
        for i in 0..m {
            let r = &self.data[i * n..(i + 1) * n];
            for (o, &x) in out.data.iter_mut().zip(r) {
                *o += x;
            }
        }
    }
}

/// Concatenate two matrices along columns: `[B, n1] ++ [B, n2] -> [B, n1+n2]`.
pub fn concat_cols(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[a.rows(), a.cols() + b.cols()]);
    concat_cols_into(a, b, &mut out);
    out
}

/// [`concat_cols`] into a caller-owned `[B, n1+n2]` tensor (row-wise
/// `copy_from_slice`, fully overwritten).
pub fn concat_cols_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let rows = a.rows();
    assert_eq!(rows, b.rows(), "concat_cols row mismatch");
    let (n1, n2) = (a.cols(), b.cols());
    assert_eq!(out.shape(), &[rows, n1 + n2], "concat_cols_into out shape");
    let n = n1 + n2;
    for i in 0..rows {
        out.data[i * n..i * n + n1].copy_from_slice(&a.data[i * n1..(i + 1) * n1]);
        out.data[i * n + n1..(i + 1) * n].copy_from_slice(&b.data[i * n2..(i + 1) * n2]);
    }
}

/// The exact dot-product reduction shared by [`Tensor::matmul_nt`] and
/// [`Tensor::matmul_nt_into`]: 4 independent accumulators over aligned
/// chunks (breaking the FP dependency chain so it vectorizes), combined as
/// `(acc0 + acc1) + (acc2 + acc3)`, then a sequential remainder. Keeping
/// this in one place is what makes the two callers bit-identical.
#[inline]
fn dot4(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len();
    let mut acc = [0.0f32; 4];
    let (ach, art) = a.split_at(k - k % 4);
    let (bch, brt) = b.split_at(k - k % 4);
    for (av, bv) in ach.chunks_exact(4).zip(bch.chunks_exact(4)) {
        acc[0] += av[0] * bv[0];
        acc[1] += av[1] * bv[1];
        acc[2] += av[2] * bv[2];
        acc[3] += av[3] * bv[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (av, bv) in art.iter().zip(brt) {
        s += av * bv;
    }
    s
}

/// Blocked GEMM kernel: C += A[m,k] @ B[k,n]. Exposed so the perf pass can
/// bench it directly.
///
/// Perf notes (EXPERIMENTS.md §Perf): i-k-j loop order with a 2-way
/// unrolled k so two B rows stream per C-row pass; the j loop is a
/// bounds-check-free `zip` that LLVM auto-vectorizes. ~3.5x over the
/// naive blocked version at SAC's 64x166x128 shape.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    const BK: usize = 128;
    for k0 in (0..k).step_by(BK) {
        let kend = (k0 + BK).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut p = k0;
            // 2-way k-unroll: one pass over crow applies two rank-1 rows.
            while p + 1 < kend {
                let a0 = arow[p];
                let a1 = arow[p + 1];
                if a0 == 0.0 && a1 == 0.0 {
                    p += 2;
                    continue;
                }
                let b0 = &b[p * n..p * n + n];
                let b1 = &b[(p + 1) * n..(p + 1) * n + n];
                for ((cj, &x0), &x1) in crow.iter_mut().zip(b0).zip(b1) {
                    *cj += a0 * x0 + a1 * x1;
                }
                p += 2;
            }
            if p < kend {
                let a0 = arow[p];
                if a0 != 0.0 {
                    let b0 = &b[p * n..p * n + n];
                    for (cj, &x0) in crow.iter_mut().zip(b0) {
                        *cj += a0 * x0;
                    }
                }
            }
        }
    }
}

/// Blocked GEMM with a 4-row register block: C += A[m,k] @ B[k,n].
///
/// Same k-blocking (128), same two-k-steps-fused inner update and same
/// single-step tail as [`matmul_into`], so every output element sees the
/// identical sequence of floating-point operations — for finite inputs the
/// result is bit-identical (the only divergence is the zero-multiplier
/// skip, which is a no-op unless the skipped operand is Inf/NaN). Four A
/// rows share each streamed pair of B rows, quartering B traffic and
/// giving the core four independent FMA chains; that, not the skip, is
/// where the speedup comes from (~1.5-2x at SAC's 64x166x128 shapes).
pub fn matmul_into_rows4(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    const BK: usize = 128;
    let m4 = m - m % 4;
    for k0 in (0..k).step_by(BK) {
        let kend = (k0 + BK).min(k);
        let mut i = 0;
        while i < m4 {
            let r0 = &a[i * k..(i + 1) * k];
            let r1 = &a[(i + 1) * k..(i + 2) * k];
            let r2 = &a[(i + 2) * k..(i + 3) * k];
            let r3 = &a[(i + 3) * k..(i + 4) * k];
            let block = &mut c[i * n..(i + 4) * n];
            let (c0, block) = block.split_at_mut(n);
            let (c1, block) = block.split_at_mut(n);
            let (c2, c3) = block.split_at_mut(n);
            let mut p = k0;
            while p + 1 < kend {
                let (a00, a01) = (r0[p], r0[p + 1]);
                let (a10, a11) = (r1[p], r1[p + 1]);
                let (a20, a21) = (r2[p], r2[p + 1]);
                let (a30, a31) = (r3[p], r3[p + 1]);
                let b0 = &b[p * n..p * n + n];
                let b1 = &b[(p + 1) * n..(p + 1) * n + n];
                for j in 0..n {
                    let x0 = b0[j];
                    let x1 = b1[j];
                    c0[j] += a00 * x0 + a01 * x1;
                    c1[j] += a10 * x0 + a11 * x1;
                    c2[j] += a20 * x0 + a21 * x1;
                    c3[j] += a30 * x0 + a31 * x1;
                }
                p += 2;
            }
            if p < kend {
                let b0 = &b[p * n..p * n + n];
                let (a0, a1, a2, a3) = (r0[p], r1[p], r2[p], r3[p]);
                for j in 0..n {
                    let x0 = b0[j];
                    c0[j] += a0 * x0;
                    c1[j] += a1 * x0;
                    c2[j] += a2 * x0;
                    c3[j] += a3 * x0;
                }
            }
            i += 4;
        }
        // Remainder rows: the original single-row kernel (identical
        // semantics, including the zero-pair skip).
        for i in m4..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            let mut p = k0;
            while p + 1 < kend {
                let a0 = arow[p];
                let a1 = arow[p + 1];
                if a0 == 0.0 && a1 == 0.0 {
                    p += 2;
                    continue;
                }
                let b0 = &b[p * n..p * n + n];
                let b1 = &b[(p + 1) * n..(p + 1) * n + n];
                for ((cj, &x0), &x1) in crow.iter_mut().zip(b0).zip(b1) {
                    *cj += a0 * x0 + a1 * x1;
                }
                p += 2;
            }
            if p < kend {
                let a0 = arow[p];
                if a0 != 0.0 {
                    let b0 = &b[p * n..p * n + n];
                    for (cj, &x0) in crow.iter_mut().zip(b0) {
                        *cj += a0 * x0;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (16, 7, 9), (33, 65, 17)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            for (x, y) in c.data().iter().zip(c0.data()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let mut rng = Rng::new(12);
        let a = Tensor::randn(&[6, 4], 1.0, &mut rng); // A is [k=6, m=4]
        let b = Tensor::randn(&[6, 5], 1.0, &mut rng);
        let c = a.matmul_tn(&b);
        let c0 = a.transpose().matmul(&b);
        for (x, y) in c.data().iter().zip(c0.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let mut rng = Rng::new(13);
        let a = Tensor::randn(&[3, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 7], 1.0, &mut rng); // B is [n=5, k=7]
        let c = a.matmul_nt(&b);
        let c0 = a.matmul(&b.transpose());
        for (x, y) in c.data().iter().zip(c0.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn add_row_and_sum_rows_are_adjoint() {
        // <x + row, y> gradient wrt row is sum_rows(y): spot-check shapes/values.
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let row = Tensor::from_vec(&[1, 3], vec![10., 20., 30.]);
        let y = x.add_row(&row);
        assert_eq!(y.data(), &[11., 22., 33., 14., 25., 36.]);
        let s = y.sum_rows();
        assert_eq!(s.data(), &[25., 47., 69.]);
    }

    #[test]
    fn axpy_and_lerp() {
        let mut a = Tensor::from_vec(&[2], vec![1., 2.]);
        let b = Tensor::from_vec(&[2], vec![10., 10.]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6., 7.]);
        a.lerp_into(0.0, &b, 1.0);
        assert_eq!(a.data(), &[10., 10.]);
    }

    #[test]
    fn reshape_checks_size() {
        let t = Tensor::zeros(&[2, 3]);
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    #[should_panic]
    fn reshape_wrong_size_panics() {
        let t = Tensor::zeros(&[2, 3]);
        let _ = t.reshape(&[4, 2]);
    }

    /// True bitwise comparison — the derived `PartialEq` (f32 `==`) would
    /// miss a `-0.0` vs `+0.0` divergence, which is exactly the class the
    /// zero-skip-vs-unconditional-add equivalence argument must exclude.
    fn assert_bits_eq(a: &Tensor, b: &Tensor, what: &str) {
        assert_eq!(a.shape(), b.shape(), "{what}: shape mismatch");
        for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
            assert!(x.to_bits() == y.to_bits(), "{what}[{i}]: {x} vs {y}");
        }
    }

    /// Sparsify ~40% of entries (half of them to `-0.0`) to exercise the
    /// zero-skip paths the allocating kernels take and the signed-zero
    /// edge of the unconditional-add kernels.
    fn sparsify(t: &mut Tensor, rng: &mut Rng) {
        for v in t.data_mut() {
            if rng.below(5) < 2 {
                *v = if rng.below(2) == 0 { 0.0 } else { -0.0 };
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_bitwise() {
        let mut rng = Rng::new(41);
        // Shapes straddle the 128 k-block, the 4-row block and odd tails.
        for &(m, k, n) in &[(1, 1, 1), (5, 3, 2), (16, 129, 9), (64, 166, 128), (7, 130, 33)] {
            let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            sparsify(&mut a, &mut rng);

            let mut out = Tensor::zeros(&[m, n]);
            a.matmul_into(&b, &mut out);
            assert_bits_eq(&a.matmul(&b), &out, &format!("matmul_into {m}x{k}x{n}"));

            let at = a.transpose(); // [k, m], so atᵀ @ b is [m, n]
            let mut out = Tensor::zeros(&[m, n]);
            at.matmul_tn_into(&b, &mut out);
            assert_bits_eq(&at.matmul_tn(&b), &out, &format!("matmul_tn_into {m}x{k}x{n}"));

            let bnt = Tensor::randn(&[n, k], 1.0, &mut rng);
            let mut out = Tensor::zeros(&[m, n]);
            a.matmul_nt_into(&bnt, &mut out);
            assert_bits_eq(&a.matmul_nt(&bnt), &out, &format!("matmul_nt_into {m}x{k}x{n}"));

            let mut out = Tensor::zeros(&[k, m]);
            a.transpose_into(&mut out);
            assert_bits_eq(&a.transpose(), &out, &format!("transpose_into {m}x{k}"));

            let row = Tensor::randn(&[1, k], 1.0, &mut rng);
            let mut out = a.clone();
            out.add_row_into(&row);
            assert_bits_eq(&a.add_row(&row), &out, &format!("add_row_into {m}x{k}"));

            let mut out = Tensor::zeros(&[1, k]);
            a.sum_rows_into(&mut out);
            assert_bits_eq(&a.sum_rows(), &out, &format!("sum_rows_into {m}x{k}"));
        }
    }

    #[test]
    fn concat_cols_into_matches_concat_cols() {
        let mut rng = Rng::new(42);
        let a = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[3, 2], 1.0, &mut rng);
        let mut out = Tensor::zeros(&[3, 6]);
        concat_cols_into(&a, &b, &mut out);
        assert_eq!(concat_cols(&a, &b), out);
    }

    #[test]
    fn copy_from_overwrites() {
        let src = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let mut dst = Tensor::zeros(&[2, 2]);
        dst.copy_from(&src);
        assert_eq!(dst, src);
    }

    #[test]
    #[should_panic(expected = "out shape")]
    fn matmul_into_checks_out_shape() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[3, 4]);
        let mut out = Tensor::zeros(&[2, 5]);
        a.matmul_into(&b, &mut out);
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(&[2, 2], vec![1., -2., 3., -4.]);
        assert_eq!(t.sum(), -2.0);
        assert_eq!(t.mean(), -0.5);
        assert_eq!(t.abs_max(), 4.0);
        assert!((t.sq_norm() - 30.0).abs() < 1e-9);
    }
}
