//! Synchronization primitives behind one shim: `std::sync` normally,
//! `loom` under `--cfg loom`.
//!
//! Every concurrent structure in this crate — `util::pool::WorkPool`,
//! `energy::cache::SharedCostCache`/`SharedCacheRegistry`, and the
//! `coordinator::service` job registry — builds on these types instead of
//! `std::sync` directly. That buys two things:
//!
//! 1. **Model checking.** Compiling with `RUSTFLAGS="--cfg loom"` swaps
//!    the backend for loom's instrumented primitives, so
//!    `rust/tests/loom_models.rs` can explore thread interleavings of the
//!    real queue/shard/registry protocols rather than a transliteration.
//! 2. **Poison recovery callers can't forget.** [`Mutex::lock`] and
//!    [`Condvar::wait`] recover the guard from a poisoned lock instead of
//!    returning `Result` (previously a free function,
//!    `util::lock_ignore_poison`, that every call site had to remember).
//!    This is only sound where the protected data's invariants hold at
//!    every panic point — pure memo caches, write-once result slots,
//!    pop-only queues, state-machine registries whose transitions are
//!    single assignments. Every `Mutex` in this crate is one of those by
//!    construction; a structure needing rollback-on-panic semantics
//!    should use `std::sync::Mutex` directly and handle `PoisonError`.
//!
//! The wrapper is intentionally thin: no timeouts, no `RwLock`, no
//! `try_lock` — the crate's lock discipline (never hold a guard across
//! an `energy::` cost computation; see `edc-lints`) keeps critical
//! sections short enough that blocking `lock()` is always right.

#[cfg(loom)]
use loom::sync as backend;
#[cfg(not(loom))]
use std::sync as backend;

pub use self::backend::{Arc, MutexGuard};

/// Atomics from the active backend (`std::sync::atomic` or `loom`'s).
pub mod atomic {
    #[cfg(loom)]
    pub use loom::sync::atomic::*;
    #[cfg(not(loom))]
    pub use std::sync::atomic::*;
}

/// Thread spawning from the active backend, so loom models see spawns as
/// schedule points. Re-exports enough of `std::thread` that callers can
/// use `sync::thread::` uniformly.
#[cfg(loom)]
pub use loom::thread;
#[cfg(not(loom))]
pub use std::thread;

/// A mutex whose `lock()` recovers from poisoning.
///
/// See the module docs for when that is sound (every use in this crate)
/// and when it is not.
pub struct Mutex<T> {
    inner: backend::Mutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Mutex<T> {
        Mutex { inner: backend::Mutex::new(value) }
    }

    /// Lock, recovering the guard if a previous holder panicked.
    ///
    /// Poisoning is a taint flag with no information for the invariants
    /// protected here; propagating it would escalate one contained
    /// worker panic into a process abort.
    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Whether a holder has panicked. Exposed for tests and diagnostics;
    /// `lock()` does not care.
    pub fn is_poisoned(&self) -> bool {
        self.inner.is_poisoned()
    }

    /// Consume the mutex, recovering the value even if poisoned.
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(|e| e.into_inner())
    }

    /// Deliberately poison this mutex by panicking while holding it.
    /// Test-only hook for the poison-recovery coverage in
    /// `tests/failure_injection.rs` and the loom models.
    #[doc(hidden)]
    pub fn poison_for_test(&self) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = self.inner.lock();
            panic!("deliberately poisoning mutex (test hook)");
        }));
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mutex {{ poisoned: {} }}", self.is_poisoned())
    }
}

/// A condition variable whose `wait()` recovers from poisoning, paired
/// with [`Mutex`] above.
pub struct Condvar {
    inner: backend::Condvar,
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar { inner: backend::Condvar::new() }
    }

    /// Block until notified, re-acquiring the guard (recovered if the
    /// notifier side panicked). Spurious wakeups are possible, exactly
    /// as with `std::sync::Condvar` — always wait in a predicate loop.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.inner.wait(guard).unwrap_or_else(|e| e.into_inner())
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_recovers_the_data_after_poisoning() {
        let m = Mutex::new(7);
        m.poison_for_test();
        assert!(m.is_poisoned());
        assert_eq!(*m.lock(), 7);
        assert_eq!(m.into_inner(), 7);
    }

    #[test]
    fn condvar_wait_roundtrips_with_wrapper_mutex() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let h = thread::spawn(move || {
            let (flag, cv) = &*p2;
            *flag.lock() = true;
            cv.notify_one();
        });
        let (flag, cv) = &*pair;
        let mut ready = flag.lock();
        while !*ready {
            ready = cv.wait(ready);
        }
        drop(ready);
        h.join().unwrap();
    }

    #[test]
    fn default_impls_build() {
        let m: Mutex<Vec<u32>> = Mutex::default();
        assert!(m.lock().is_empty());
        let _cv = Condvar::default();
    }
}
