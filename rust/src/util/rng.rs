//! Deterministic pseudo-random number generation.
//!
//! Implements `SplitMix64` (seeding) and `xoshiro256**` (stream), the
//! standard pairing recommended by Blackman & Vigna. Gaussian variates use
//! the Marsaglia polar method. Everything is reproducible from a `u64`
//! seed, which the CLI exposes as `--seed` so every experiment in
//! `EXPERIMENTS.md` can be replayed bit-for-bit.

/// SplitMix64 — used to expand a user seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Derive the root seed of an independent, deterministic sub-stream.
///
/// `seed_stream(base, i)` and `seed_stream(base, j)` are decorrelated for
/// `i != j` but each is a pure function of `(base, stream)` — unlike
/// [`Rng::fork`], which consumes state from the parent generator. The
/// multi-seed orchestrator uses this to give every concurrent search its
/// own agent/oracle streams that can be re-derived identically on resume.
pub fn seed_stream(base: u64, stream: u64) -> u64 {
    let mut sm = SplitMix64::new(base ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // One extra scramble so adjacent (base, stream) pairs don't land on
    // adjacent SplitMix64 walks.
    SplitMix64::new(sm.next_u64()).next_u64()
}

/// xoshiro256** generator with convenience sampling methods.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian variate from the polar method.
    spare: Option<f64>,
}

impl Rng {
    /// Construct from a user seed (any value, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare: None,
        }
    }

    /// Derive an independent child stream (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The full generator state: the xoshiro words plus the cached polar
    /// spare. Together with [`Rng::from_state`] this makes the stream
    /// checkpointable mid-sequence (bit-identical continuation).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator at an exact point of its stream (see
    /// [`Rng::state`]).
    pub fn from_state(s: [u64; 4], spare: Option<f64>) -> Rng {
        Rng { s, spare }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = self.range(-1.0, 1.0);
            let v = self.range(-1.0, 1.0);
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std) f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f64) {
        for v in out.iter_mut() {
            *v = (self.normal() * std) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Bernoulli draw.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(11);
        // Burn an odd number of normals so a polar spare is likely cached.
        for _ in 0..7 {
            a.normal();
        }
        let (s, spare) = a.state();
        let mut b = Rng::from_state(s, spare);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
    }

    #[test]
    fn seed_stream_is_pure_and_decorrelated() {
        assert_eq!(seed_stream(42, 3), seed_stream(42, 3));
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            assert!(seen.insert(seed_stream(42, i)), "collision at stream {i}");
        }
        assert_ne!(seed_stream(1, 0), seed_stream(2, 0));
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = Rng::new(7);
        let mut c1 = a.fork(1);
        let mut c2 = a.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let (mut sum, mut sq, mut kurt) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
            kurt += v * v * v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        let k = kurt / n as f64 / (var * var);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
        assert!((k - 3.0).abs() < 0.15, "kurtosis {k}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(50, 20);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20);
    }
}
