//! A minimal JSON codec (no `serde` offline).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP. Used for experiment configs, checkpoints and CSV-adjacent report
//! metadata. Round-trip tested below and property-tested in
//! `rust/tests/prop_invariants.rs`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for reproducible checkpoints.
///
/// The `F32s`/`F64s`/`U32s` variants are *typed leaves*: numeric arrays
/// held in native storage instead of `Arr(Num)`. The binary snapshot
/// codec (`snapshot::BinaryCodec`) produces them when reading v4 blob
/// sections, and their `Display` output is byte-identical to the
/// equivalent `Arr(Num)` emission (each element widened to f64 and
/// formatted by the same rule, non-finite as `null`), so a tree that
/// carries typed leaves serializes to exactly the JSON the all-`Arr`
/// tree would. The text parser never produces them.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
    F32s(Vec<f32>),
    F64s(Vec<f64>),
    U32s(Vec<u32>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Fetch a number field or return `default`.
    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|j| j.as_f64()).unwrap_or(default)
    }

    /// Fetch a string field or return `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|j| j.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn to_f64s(&self) -> Option<Vec<f64>> {
        match self {
            Json::F64s(v) => return Some(v.clone()),
            Json::F32s(v) => return Some(v.iter().map(|&x| f64::from(x)).collect()),
            Json::U32s(v) => return Some(v.iter().map(|&x| f64::from(x)).collect()),
            _ => {}
        }
        let a = self.as_arr()?;
        let mut out = Vec::with_capacity(a.len());
        for j in a {
            match j {
                Json::Num(v) => out.push(*v),
                // The writer emits non-finite numbers as `null`; restore
                // them as NaN so float arrays round-trip length-preserving
                // (accuracy curves carry NaN before the first admissible
                // point — dropping entries here silently shortened them).
                Json::Null => out.push(f64::NAN),
                _ => return None,
            }
        }
        Some(out)
    }

    /// Read an f32 array from either a typed `F32s` leaf (v4 binary
    /// snapshots) or an `Arr` of finite `Num`s (v3 JSON). Strict on
    /// `Null`/non-numeric entries, mirroring `rl::sac::f32s_from_json`:
    /// f32 payloads (weights, replay vectors) never carry non-finite
    /// placeholders, so a `null` there is corruption, not a NaN.
    pub fn as_f32s(&self) -> Option<Vec<f32>> {
        match self {
            Json::F32s(v) => Some(v.clone()),
            Json::Arr(a) => {
                let mut out = Vec::with_capacity(a.len());
                for j in a {
                    out.push(j.as_f64()? as f32);
                }
                Some(out)
            }
            _ => None,
        }
    }

    /// Read a u32 array from either a typed `U32s` leaf or an `Arr` of
    /// non-negative integral `Num`s (tensor shapes).
    pub fn as_u32s(&self) -> Option<Vec<u32>> {
        match self {
            Json::U32s(v) => Some(v.clone()),
            Json::Arr(a) => {
                let mut out = Vec::with_capacity(a.len());
                for j in a {
                    let v = j.as_f64()?;
                    if v < 0.0 || v != v.trunc() || v > f64::from(u32::MAX) {
                        return None;
                    }
                    out.push(v as u32);
                }
                Some(out)
            }
            _ => None,
        }
    }

    /// Element count if this value is an array of any representation
    /// (`Arr` or a typed leaf).
    pub fn arr_len(&self) -> Option<usize> {
        match self {
            Json::Arr(v) => Some(v.len()),
            Json::F32s(v) => Some(v.len()),
            Json::F64s(v) => Some(v.len()),
            Json::U32s(v) => Some(v.len()),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self, f)
    }
}

/// The one number-formatting rule, shared by `Num` and the typed-leaf
/// arrays so their bytes can never diverge: integral values below 1e15
/// print via i64 (no trailing `.0`), other finite values use Rust's
/// shortest round-trip formatting, non-finite prints `null` (JSON has
/// no Inf/NaN; most encoders do the same).
fn write_f64(v: f64, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    if v.is_finite() {
        if v == v.trunc() && v.abs() < 1e15 {
            write!(f, "{}", v as i64)
        } else {
            write!(f, "{v}")
        }
    } else {
        write!(f, "null")
    }
}

/// Emit a typed numeric array exactly as the equivalent `Arr(Num)`.
fn write_f64_array<I: Iterator<Item = f64>>(it: I, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "[")?;
    for (i, v) in it.enumerate() {
        if i > 0 {
            write!(f, ",")?;
        }
        write_f64(v, f)?;
    }
    write!(f, "]")
}

fn write_json(j: &Json, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match j {
        Json::Null => write!(f, "null"),
        Json::Bool(b) => write!(f, "{b}"),
        Json::Num(v) => write_f64(*v, f),
        Json::Str(s) => write_escaped(s, f),
        Json::Arr(v) => {
            write!(f, "[")?;
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_json(x, f)?;
            }
            write!(f, "]")
        }
        Json::Obj(m) => {
            write!(f, "{{")?;
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_escaped(k, f)?;
                write!(f, ":")?;
                write_json(v, f)?;
            }
            write!(f, "}}")
        }
        Json::F32s(v) => write_f64_array(v.iter().map(|&x| f64::from(x)), f),
        Json::F64s(v) => write_f64_array(v.iter().copied(), f),
        Json::U32s(v) => write_f64_array(v.iter().map(|&x| f64::from(x)), f),
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse errors carry byte offsets for debuggability.
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: re-decode from the original slice.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    self.pos = start + width;
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "3.5", "-2", "\"hi\""] {
            let v = parse(s).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let s = r#"{"a": [1, 2.5, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = parse(s).unwrap();
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = parse(r#""tab\t quote\" unicode é snow☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "tab\t quote\" unicode é snow☃");
        let utf8 = parse("\"héllo ☃\"").unwrap();
        assert_eq!(utf8.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn accessors() {
        let mut o = Json::obj();
        o.set("x", Json::Num(4.0)).set("s", Json::Str("v".into()));
        assert_eq!(o.num_or("x", 0.0), 4.0);
        assert_eq!(o.num_or("missing", 7.0), 7.0);
        assert_eq!(o.str_or("s", ""), "v");
    }

    #[test]
    fn f64s_helpers() {
        let j = Json::from_f64s(&[1.0, 2.0, 3.0]);
        assert_eq!(j.to_f64s().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn f64s_round_trip_preserves_nan_positions() {
        let orig = [f64::NAN, 1.5, f64::NAN, 2.0];
        let text = Json::from_f64s(&orig).to_string();
        assert_eq!(text, "[null,1.5,null,2]");
        let back = parse(&text).unwrap().to_f64s().unwrap();
        assert_eq!(back.len(), orig.len());
        assert!(back[0].is_nan() && back[2].is_nan());
        assert_eq!((back[1], back[3]), (1.5, 2.0));
    }

    #[test]
    fn f64s_rejects_non_numeric_entries() {
        assert!(parse(r#"[1,"x"]"#).unwrap().to_f64s().is_none());
        assert!(parse("[true]").unwrap().to_f64s().is_none());
    }

    #[test]
    fn deterministic_object_order() {
        let mut o = Json::obj();
        o.set("zeta", Json::Num(1.0)).set("alpha", Json::Num(2.0));
        assert_eq!(o.to_string(), r#"{"alpha":2,"zeta":1}"#);
    }

    /// The v4 bit-identity cornerstone: a typed leaf must serialize to
    /// exactly the bytes the equivalent `Arr(Num)` serializes to, for
    /// every formatting branch (integral, fractional, sub-f32 precision,
    /// non-finite).
    #[test]
    fn typed_leaves_display_byte_identical_to_arr() {
        let f64s = vec![0.0, -1.0, 1.5, 1e-300, 0.1 + 0.2, f64::NAN, f64::INFINITY, 3e15];
        let arr = Json::from_f64s(&f64s);
        assert_eq!(Json::F64s(f64s.clone()).to_string(), arr.to_string());

        let f32s: Vec<f32> = vec![0.0, -2.0, 0.1, 1e-30, f32::NAN, 7.25];
        let widened = Json::Arr(f32s.iter().map(|&x| Json::Num(f64::from(x))).collect());
        assert_eq!(Json::F32s(f32s).to_string(), widened.to_string());

        let u32s = vec![0u32, 1, 500, u32::MAX];
        let nums = Json::Arr(u32s.iter().map(|&x| Json::Num(f64::from(x))).collect());
        assert_eq!(Json::U32s(u32s).to_string(), nums.to_string());
    }

    #[test]
    fn typed_accessors_accept_both_representations() {
        let arr = parse("[1,2.5,3]").unwrap();
        assert_eq!(arr.as_f32s().unwrap(), vec![1.0, 2.5, 3.0]);
        assert_eq!(Json::F32s(vec![1.0, 2.5, 3.0]).as_f32s().unwrap(), vec![1.0, 2.5, 3.0]);
        // Strict: null entries are corruption for f32 payloads.
        assert!(parse("[1,null]").unwrap().as_f32s().is_none());

        let shape = parse("[64,166]").unwrap();
        assert_eq!(shape.as_u32s().unwrap(), vec![64, 166]);
        assert_eq!(Json::U32s(vec![64, 166]).as_u32s().unwrap(), vec![64, 166]);
        assert!(parse("[-1]").unwrap().as_u32s().is_none());
        assert!(parse("[1.5]").unwrap().as_u32s().is_none());

        // to_f64s reads all three typed leaves; F64s preserves NaN.
        let back = Json::F64s(vec![f64::NAN, 2.0]).to_f64s().unwrap();
        assert!(back[0].is_nan());
        assert_eq!(back[1], 2.0);
        assert_eq!(Json::U32s(vec![3]).to_f64s().unwrap(), vec![3.0]);
        assert_eq!(Json::F32s(vec![0.5]).to_f64s().unwrap(), vec![0.5]);

        assert_eq!(Json::F64s(vec![1.0; 4]).arr_len(), Some(4));
        assert_eq!(parse("[1,2]").unwrap().arr_len(), Some(2));
        assert_eq!(Json::Num(1.0).arr_len(), None);
    }
}
