//! Bounds-checked binary blob storage for v4 snapshots.
//!
//! A v4 snapshot is a JSON header followed by one contiguous
//! little-endian payload of 8-byte-aligned numeric sections (see
//! `snapshot::BinaryCodec` for the container layout and
//! `docs/checkpoints.md` for the on-disk spec). This module owns the
//! two halves of that payload's lifecycle:
//!
//! - [`BlobWriter`] appends f32/f64/u32 sections, padding each to an
//!   8-byte boundary, and returns the byte offset where the section
//!   landed — the offsets the header's field table records.
//! - [`BlobReader`] opens a file via `mmap` when available (unix; the
//!   mapping is read-only and private) with a read-to-aligned-`Vec`
//!   fallback, and hands out zero-copy `&[f32]`/`&[f64]`/`&[u32]`
//!   section views. Every view is bounds- and alignment-checked against
//!   the real file size first, and a failed check produces a readable
//!   error naming the file, the field, and the byte offset — a corrupt
//!   or truncated snapshot must never panic (or worse, read out of
//!   bounds).
//!
//! The zero-copy views reinterpret raw bytes, so they are only correct
//! on little-endian hosts; the format itself is defined as
//! little-endian and the build refuses big-endian targets below rather
//! than silently byte-swapping.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context};

#[cfg(target_endian = "big")]
compile_error!(
    "v4 snapshot blobs are little-endian and read zero-copy; \
     big-endian hosts would need a byte-swapping decode path"
);

/// Append-only builder for the numeric payload of a v4 snapshot.
/// Sections start 8-byte aligned (the alignment of the widest dtype),
/// with zero padding between them, so any section can be viewed in
/// place once the blob itself is loaded at an 8-aligned base address.
#[derive(Default)]
pub struct BlobWriter {
    buf: Vec<u8>,
}

impl BlobWriter {
    pub fn new() -> BlobWriter {
        BlobWriter::default()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    fn pad8(&mut self) {
        while self.buf.len() % 8 != 0 {
            self.buf.push(0);
        }
    }

    /// Append an f32 section; returns its byte offset within the blob.
    pub fn push_f32s(&mut self, vals: &[f32]) -> usize {
        self.pad8();
        let off = self.buf.len();
        for v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        off
    }

    /// Append an f64 section; returns its byte offset within the blob.
    pub fn push_f64s(&mut self, vals: &[f64]) -> usize {
        self.pad8();
        let off = self.buf.len();
        for v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        off
    }

    /// Append a u32 section; returns its byte offset within the blob.
    pub fn push_u32s(&mut self, vals: &[u32]) -> usize {
        self.pad8();
        let off = self.buf.len();
        for v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        off
    }

    pub fn into_bytes(mut self) -> Vec<u8> {
        self.pad8();
        self.buf
    }
}

/// Byte storage whose base address is always 8-byte aligned (backed by
/// a `Vec<u64>`), so dtype-aligned section offsets yield dtype-aligned
/// element pointers.
struct AlignedBytes {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBytes {
    fn from_slice(b: &[u8]) -> AlignedBytes {
        let mut words = vec![0u64; b.len().div_ceil(8)];
        // Safety: the word buffer spans at least `b.len()` bytes.
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), words.as_mut_ptr().cast::<u8>(), b.len());
        }
        AlignedBytes { words, len: b.len() }
    }

    fn bytes(&self) -> &[u8] {
        // Safety: `len <= words.len() * 8` by construction.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr().cast::<u8>(), self.len) }
    }
}

#[cfg(unix)]
mod mm {
    use std::ffi::c_void;

    // libc is always linked via std on unix; declaring the two symbols
    // directly avoids growing a dependency for one syscall pair.
    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// Map `len` bytes of `fd` read-only; `None` on failure (callers
    /// fall back to reading the file).
    pub fn map(fd: i32, len: usize) -> Option<*const u8> {
        // Safety: a read-only private mapping of an open fd; failure is
        // reported as MAP_FAILED, checked below.
        let p = unsafe { mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, fd, 0) };
        if p as usize == usize::MAX || p.is_null() {
            None
        } else {
            Some(p.cast_const().cast::<u8>())
        }
    }

    pub fn unmap(ptr: *const u8, len: usize) {
        // Safety: `ptr`/`len` came from a successful `map` call.
        unsafe {
            let _ = munmap(ptr.cast_mut().cast::<c_void>(), len);
        }
    }
}

enum Backing {
    Owned(AlignedBytes),
    #[cfg(unix)]
    Mapped { ptr: *const u8, len: usize },
}

/// Read side of the blob: the raw bytes of one snapshot file plus the
/// origin path for error messages. Section accessors give zero-copy
/// typed views after bounds and alignment checks.
pub struct BlobReader {
    backing: Backing,
    origin: String,
}

// Safety: the mapped region is read-only and private; `BlobReader`
// hands out only shared references to it.
unsafe impl Send for BlobReader {}
unsafe impl Sync for BlobReader {}

impl Drop for BlobReader {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self.backing {
            mm::unmap(ptr, len);
        }
    }
}

impl BlobReader {
    /// Open `path`, mmap'd when the platform allows, otherwise read
    /// into aligned owned storage.
    pub fn open(path: &Path) -> anyhow::Result<BlobReader> {
        let origin = path.display().to_string();
        let file =
            std::fs::File::open(path).with_context(|| format!("reading snapshot {origin}"))?;
        let len = file
            .metadata()
            .with_context(|| format!("reading snapshot {origin}"))?
            .len();
        let len = usize::try_from(len)
            .map_err(|_| anyhow::anyhow!("{origin}: file too large to map"))?;
        #[cfg(unix)]
        if len > 0 {
            use std::os::unix::io::AsRawFd;
            if let Some(ptr) = mm::map(file.as_raw_fd(), len) {
                return Ok(BlobReader { backing: Backing::Mapped { ptr, len }, origin });
            }
        }
        let bytes = std::fs::read(path).with_context(|| format!("reading snapshot {origin}"))?;
        Ok(BlobReader::from_vec(bytes, &origin))
    }

    /// Wrap in-memory bytes (copied into aligned storage), e.g. for
    /// decoding a snapshot that was never written to disk.
    pub fn from_vec(bytes: Vec<u8>, origin: &str) -> BlobReader {
        BlobReader {
            backing: Backing::Owned(AlignedBytes::from_slice(&bytes)),
            origin: origin.to_string(),
        }
    }

    /// The file path (or synthetic origin label) used in error messages.
    pub fn origin(&self) -> &str {
        &self.origin
    }

    /// The whole file, as bytes at an 8-aligned base address.
    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            Backing::Owned(a) => a.bytes(),
            // Safety: the mapping stays valid until `Drop`.
            #[cfg(unix)]
            Backing::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
        }
    }

    fn section<T>(&self, field: &str, off: usize, count: usize, dtype: &str) -> anyhow::Result<&[T]> {
        let bytes = self.bytes();
        let size = std::mem::size_of::<T>();
        let byte_len = count
            .checked_mul(size)
            .with_context(|| self.section_err(field, off, dtype, "section length overflows"))?;
        let end = off
            .checked_add(byte_len)
            .with_context(|| self.section_err(field, off, dtype, "section end overflows"))?;
        if end > bytes.len() {
            bail!(self.section_err(
                field,
                off,
                dtype,
                &format!(
                    "section of {byte_len} bytes runs past the end of the {}-byte file",
                    bytes.len()
                ),
            ));
        }
        if off % size != 0 {
            bail!(self.section_err(field, off, dtype, &format!("offset is not {size}-byte aligned")));
        }
        // Safety: bounds and alignment checked above; the base address
        // is 8-aligned (mmap is page-aligned, Owned is Vec<u64>-backed),
        // so `base + off` is `size_of::<T>()`-aligned. T is one of the
        // plain-old-data section dtypes (f32/f64/u32) for which any bit
        // pattern is a valid value.
        unsafe { Ok(std::slice::from_raw_parts(bytes.as_ptr().add(off).cast::<T>(), count)) }
    }

    fn section_err(&self, field: &str, off: usize, dtype: &str, what: &str) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{}: field `{field}`: {dtype} section at byte offset {off}: {what}",
            self.origin
        );
        s
    }

    /// Zero-copy f32 section view (`count` elements at byte `off`).
    pub fn f32s(&self, field: &str, off: usize, count: usize) -> anyhow::Result<&[f32]> {
        self.section::<f32>(field, off, count, "f32")
    }

    /// Zero-copy f64 section view (`count` elements at byte `off`).
    pub fn f64s(&self, field: &str, off: usize, count: usize) -> anyhow::Result<&[f64]> {
        self.section::<f64>(field, off, count, "f64")
    }

    /// Zero-copy u32 section view (`count` elements at byte `off`).
    pub fn u32s(&self, field: &str, off: usize, count: usize) -> anyhow::Result<&[u32]> {
        self.section::<u32>(field, off, count, "u32")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_round_trips_all_dtypes_through_reader() {
        let mut w = BlobWriter::new();
        let o32 = w.push_f32s(&[1.0, -2.5, f32::NAN]);
        let o64 = w.push_f64s(&[0.1, f64::NAN, -3.0]);
        let ou = w.push_u32s(&[7, 0, u32::MAX]);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len() % 8, 0);
        assert_eq!(o32 % 8, 0);
        assert_eq!(o64 % 8, 0);
        assert_eq!(ou % 8, 0);

        let r = BlobReader::from_vec(bytes, "mem");
        let f = r.f32s("a", o32, 3).unwrap();
        assert_eq!(f[0].to_bits(), 1.0f32.to_bits());
        assert_eq!(f[2].to_bits(), f32::NAN.to_bits());
        let d = r.f64s("b", o64, 3).unwrap();
        assert_eq!(d[1].to_bits(), f64::NAN.to_bits());
        assert_eq!(d[2], -3.0);
        assert_eq!(r.u32s("c", ou, 3).unwrap(), &[7, 0, u32::MAX]);
    }

    #[test]
    fn out_of_bounds_and_misaligned_sections_error_readably() {
        let mut w = BlobWriter::new();
        w.push_f64s(&[1.0, 2.0]);
        let r = BlobReader::from_vec(w.into_bytes(), "snap.edc4");

        let e = r.f64s("slots.0.curve", 8, 4).unwrap_err().to_string();
        assert!(e.contains("snap.edc4"), "{e}");
        assert!(e.contains("slots.0.curve"), "{e}");
        assert!(e.contains("offset 8"), "{e}");
        assert!(e.contains("runs past the end"), "{e}");

        let e = r.f64s("x", 4, 1).unwrap_err().to_string();
        assert!(e.contains("not 8-byte aligned"), "{e}");

        let e = r.f32s("y", usize::MAX - 2, 1).unwrap_err().to_string();
        assert!(e.contains("overflows"), "{e}");

        // In-bounds aligned view still works alongside the failures.
        assert_eq!(r.f64s("ok", 0, 2).unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn open_uses_real_files_and_empty_files_are_fine() {
        let dir = std::env::temp_dir().join("edc_blob_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("blob_{}.bin", std::process::id()));

        let mut w = BlobWriter::new();
        let off = w.push_u32s(&[3, 1, 4, 1, 5]);
        std::fs::write(&path, w.into_bytes()).unwrap();
        let r = BlobReader::open(&path).unwrap();
        assert_eq!(r.u32s("digits", off, 5).unwrap(), &[3, 1, 4, 1, 5]);
        assert!(r.origin().contains("blob_"), "{}", r.origin());
        drop(r);

        std::fs::write(&path, b"").unwrap();
        let r = BlobReader::open(&path).unwrap();
        assert!(r.bytes().is_empty());
        let e = r.f32s("w", 0, 1).unwrap_err().to_string();
        assert!(e.contains("0-byte file"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_error_names_the_path() {
        let e = BlobReader::open(Path::new("/nonexistent/edc_nope.bin"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("edc_nope.bin"), "{e}");
    }
}
