//! Minimal `log`-facade backend writing to stderr with timestamps.
//!
//! `EDC_LOG=debug|info|warn|error` selects verbosity (default `info`).

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    max: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            eprintln!(
                "[{t:9.3}s {:5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; repeated calls are no-ops.
pub fn init() {
    let level = match std::env::var("EDC_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let logger = Box::new(StderrLogger {
        start: Instant::now(),
        max: level,
    });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::Trace);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init(); // second call must not panic
        log::info!("logging smoke test");
    }
}
