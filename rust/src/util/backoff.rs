//! Deadlines, decorrelated-jitter retry backoff, and the per-backend
//! circuit breaker the router daemon drives its health state machine
//! with.
//!
//! Everything here is deterministic given a seed: jitter comes from
//! [`util::rng::Rng`](crate::util::rng) (never ambient entropy — lint
//! rule 2), and the [`Breaker`] takes time as a caller-supplied logical
//! clock in milliseconds rather than sampling `Instant::now` itself.
//! That split is what lets `tests/loom_models.rs` model-check the
//! healthy → degraded → quarantined transitions with a counter for a
//! clock, while the router's health loop feeds it real elapsed
//! milliseconds. The breaker's interior state lives behind the
//! [`util::sync`](crate::util::sync) shim so loom sees the real lock
//! protocol, not a transliteration.
//!
//! The retry policy is "decorrelated jitter" (the AWS architecture-blog
//! variant): each delay is uniform in `[base, 3 * previous]`, clamped to
//! `[base, cap]`. Compared with plain exponential backoff it decorrelates
//! a thundering herd of clients that all saw the same `retry_after_ms`
//! hint, while still growing the expected delay geometrically.

use crate::util::rng::Rng;
use crate::util::sync::Mutex;
use std::time::{Duration, Instant};

/// A point in time a blocking operation must not run past.
///
/// Thin wrapper over `Instant` so call sites read as intent
/// (`deadline.expired()`) and so the remaining budget can be handed to
/// `set_read_timeout`-style APIs without re-deriving it.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `d` from now.
    pub fn after(d: Duration) -> Deadline {
        Deadline { at: Instant::now() + d }
    }

    /// Time left before the deadline, zero once passed.
    pub fn remaining(&self) -> Duration {
        self.at.saturating_duration_since(Instant::now())
    }

    /// Whether the deadline has passed.
    pub fn expired(&self) -> bool {
        self.remaining() == Duration::ZERO
    }

    /// `remaining()` clamped below by one millisecond, for APIs where a
    /// zero timeout means "wait forever" (`set_read_timeout`).
    pub fn remaining_or_min(&self) -> Duration {
        self.remaining().max(Duration::from_millis(1))
    }
}

/// Decorrelated-jitter retry delays: each delay is uniform in
/// `[base, 3 * previous]`, clamped to `[base, cap]`.
#[derive(Clone, Debug)]
pub struct Backoff {
    base_ms: u64,
    cap_ms: u64,
    prev_ms: u64,
    rng: Rng,
}

impl Backoff {
    /// `base`/`cap` bound every delay; `seed` makes the jitter stream
    /// replayable (clients derive it from their RNG, tests pin it).
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        let base_ms = (base.as_millis() as u64).max(1);
        let cap_ms = (cap.as_millis() as u64).max(base_ms);
        Backoff { base_ms, cap_ms, prev_ms: base_ms, rng: Rng::new(seed) }
    }

    /// Next delay in the decorrelated-jitter sequence.
    pub fn next_delay(&mut self) -> Duration {
        let hi = (self.prev_ms.saturating_mul(3)).clamp(self.base_ms + 1, self.cap_ms.max(self.base_ms + 1));
        let pick = self.rng.range(self.base_ms as f64, hi as f64) as u64;
        self.prev_ms = pick.clamp(self.base_ms, self.cap_ms);
        Duration::from_millis(self.prev_ms)
    }

    /// Next delay, but never shorter than a server-supplied
    /// `retry_after_ms` hint — honoring the daemon's own estimate of
    /// when capacity frees up while keeping the jitter on top.
    pub fn next_delay_after(&mut self, retry_after_ms: u64) -> Duration {
        // Let the hint also raise the floor of future delays, so a
        // client retrying against a saturated queue ramps from the
        // server's estimate instead of from `base`.
        self.prev_ms = self.prev_ms.max(retry_after_ms.min(self.cap_ms));
        self.next_delay().max(Duration::from_millis(retry_after_ms))
    }

    /// Reset to the base delay (after a success).
    pub fn reset(&mut self) {
        self.prev_ms = self.base_ms;
    }
}

/// Health of one routed backend, as the router's circuit breaker sees
/// it. Transitions (all driven by [`Breaker`]):
///
/// ```text
/// Healthy --failure--> Degraded --failure (strikes >= threshold)--> Quarantined
///    ^                    |                                             |
///    +----- success ------+<------- probe success (via on_success) -----+
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Recent probes and requests succeeded; route freely.
    Healthy,
    /// Under the strike threshold: still admitted, but suspect.
    Degraded,
    /// Tripped: no traffic until a jittered-backoff probe succeeds.
    Quarantined,
}

impl BreakerState {
    pub fn label(self) -> &'static str {
        match self {
            BreakerState::Healthy => "healthy",
            BreakerState::Degraded => "degraded",
            BreakerState::Quarantined => "quarantined",
        }
    }
}

struct BreakerInner {
    state: BreakerState,
    strikes: u32,
    /// Logical-clock instant (ms) at which a quarantined backend may be
    /// re-probed. Meaningless outside `Quarantined`.
    probe_at_ms: u64,
    backoff: Backoff,
}

/// Circuit breaker for one backend: counts consecutive failures,
/// quarantines at a threshold, and schedules re-probes with
/// decorrelated-jitter exponential backoff.
///
/// Time is a caller-supplied monotone `now_ms`; the breaker never reads
/// a clock. Interior mutability is a [`util::sync::Mutex`]
/// (crate::util::sync), so the health loop, the routing path, and the
/// loom model all contend on the real lock.
pub struct Breaker {
    inner: Mutex<BreakerInner>,
    threshold: u32,
}

impl Breaker {
    /// `threshold` consecutive failures trip the breaker; probe delays
    /// jitter in `[probe_base, probe_cap]`, growing per failed probe.
    pub fn new(threshold: u32, probe_base: Duration, probe_cap: Duration, seed: u64) -> Breaker {
        Breaker {
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Healthy,
                strikes: 0,
                probe_at_ms: 0,
                backoff: Backoff::new(probe_base, probe_cap, seed),
            }),
            threshold: threshold.max(1),
        }
    }

    /// A request or probe succeeded: fully reset to `Healthy`.
    pub fn on_success(&self) {
        let mut g = self.inner.lock();
        g.state = BreakerState::Healthy;
        g.strikes = 0;
        g.backoff.reset();
    }

    /// A request or probe failed at logical time `now_ms`. Returns the
    /// state after the transition, so callers can act on the
    /// degraded→quarantined edge (e.g. fail over in-flight jobs).
    pub fn on_failure(&self, now_ms: u64) -> BreakerState {
        let mut g = self.inner.lock();
        g.strikes = g.strikes.saturating_add(1);
        if g.strikes >= self.threshold {
            g.state = BreakerState::Quarantined;
            let delay = g.backoff.next_delay();
            g.probe_at_ms = now_ms.saturating_add(delay.as_millis() as u64);
        } else {
            g.state = BreakerState::Degraded;
        }
        g.state
    }

    /// Whether new work may be routed here (`Healthy` or `Degraded`).
    pub fn admit(&self) -> bool {
        self.inner.lock().state != BreakerState::Quarantined
    }

    /// Whether a quarantined backend's backoff has elapsed and it should
    /// be pinged again. Always false outside `Quarantined`.
    pub fn probe_due(&self, now_ms: u64) -> bool {
        let g = self.inner.lock();
        g.state == BreakerState::Quarantined && now_ms >= g.probe_at_ms
    }

    pub fn state(&self) -> BreakerState {
        self.inner.lock().state
    }

    /// Consecutive-failure count (diagnostics / `status` reporting).
    pub fn strikes(&self) -> u32 {
        self.inner.lock().strikes
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn deadline_expires() {
        let d = Deadline::after(Duration::from_millis(20));
        assert!(!d.expired());
        assert!(d.remaining() <= Duration::from_millis(20));
        std::thread::sleep(Duration::from_millis(30));
        assert!(d.expired());
        assert_eq!(d.remaining(), Duration::ZERO);
        assert_eq!(d.remaining_or_min(), Duration::from_millis(1));
    }

    #[test]
    fn backoff_is_bounded_and_deterministic() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(500);
        let mut a = Backoff::new(base, cap, 42);
        let mut b = Backoff::new(base, cap, 42);
        let mut grew = false;
        for _ in 0..32 {
            let da = a.next_delay();
            assert_eq!(da, b.next_delay(), "same seed, same jitter stream");
            assert!((base..=cap).contains(&da), "delay {da:?} outside [{base:?}, {cap:?}]");
            grew |= da > base;
        }
        assert!(grew, "decorrelated jitter should grow past the base at least once");
        let mut c = Backoff::new(base, cap, 43);
        let diverges = (0..8).any(|_| a.next_delay() != c.next_delay());
        assert!(diverges, "different seeds should decorrelate");
    }

    #[test]
    fn backoff_honors_retry_after_hint() {
        let mut b = Backoff::new(Duration::from_millis(5), Duration::from_secs(2), 7);
        let d = b.next_delay_after(250);
        assert!(d >= Duration::from_millis(250), "hint is a floor, got {d:?}");
        assert!(d <= Duration::from_secs(2));
        // The hint also ratchets the sequence: the next plain delay
        // jitters from the hinted floor, not from base.
        assert!(b.next_delay() >= Duration::from_millis(5));
    }

    #[test]
    fn backoff_reset_returns_to_base() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 1);
        for _ in 0..8 {
            b.next_delay();
        }
        b.reset();
        // First post-reset delay is drawn from [base, 3*base].
        assert!(b.next_delay() <= Duration::from_millis(30));
    }

    #[test]
    fn breaker_trips_at_threshold_and_reprobes_after_backoff() {
        let br = Breaker::new(3, Duration::from_millis(100), Duration::from_secs(5), 11);
        assert_eq!(br.state(), BreakerState::Healthy);
        assert!(br.admit());

        assert_eq!(br.on_failure(0), BreakerState::Degraded);
        assert!(br.admit(), "degraded still admits");
        assert_eq!(br.on_failure(10), BreakerState::Degraded);
        assert_eq!(br.on_failure(20), BreakerState::Quarantined);
        assert!(!br.admit(), "quarantined sheds traffic");
        assert_eq!(br.strikes(), 3);

        // The probe is not due immediately: the jittered delay is at
        // least the 100 ms base.
        assert!(!br.probe_due(20));
        assert!(!br.probe_due(119));
        assert!(br.probe_due(20 + 5_000), "due once the cap has elapsed");

        // A failed probe re-quarantines with a longer (bounded) delay.
        assert_eq!(br.on_failure(6_000), BreakerState::Quarantined);
        assert!(!br.probe_due(6_000));

        // A successful probe fully resets.
        br.on_success();
        assert_eq!(br.state(), BreakerState::Healthy);
        assert!(br.admit());
        assert_eq!(br.strikes(), 0);
        assert!(!br.probe_due(u64::MAX), "probe_due is only meaningful in quarantine");
    }

    #[test]
    fn breaker_success_resets_strike_count_mid_degrade() {
        let br = Breaker::new(3, Duration::from_millis(50), Duration::from_secs(1), 2);
        br.on_failure(0);
        br.on_failure(1);
        br.on_success();
        // Two more failures only reach Degraded again: strikes restarted.
        assert_eq!(br.on_failure(2), BreakerState::Degraded);
        assert_eq!(br.on_failure(3), BreakerState::Degraded);
        assert_eq!(br.on_failure(4), BreakerState::Quarantined);
    }
}
