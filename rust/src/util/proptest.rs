//! A miniature property-testing harness (no `proptest` crate offline).
//!
//! Provides `check(name, cases, |rng| ...)` which runs a closure over many
//! seeded random cases; on failure it retries with the *same* seed while
//! halving a scale hint so the failure report carries the smallest seed it
//! saw fail (a poor man's shrinking), then panics with the reproducer seed.
//!
//! Used by the `prop_*` integration tests on cost-model and RL invariants.

use crate::util::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of property `f`. Each case gets a fresh
/// deterministic RNG; failures panic with the seed so they can be replayed
/// with `PROP_SEED=<n>`.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xEDC0_FFEE);
    let cases = if std::env::var("PROP_SEED").is_ok() { 1 } else { cases };
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {i} (replay with PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert two floats are close; returns a CaseResult for use inside checks.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> CaseResult {
    let denom = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() / denom <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Assert a predicate with a formatted message.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 25, |_rng| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng| {
            ensure(rng.uniform() < -1.0, "always false")
        });
    }

    #[test]
    fn close_relative() {
        assert!(close(1000.0, 1000.5, 1e-3, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-3, "x").is_err());
    }
}
