//! Offline substrates: PRNG, JSON codec, statistics, logging and a
//! miniature property-testing harness.
//!
//! The build environment has no network access and the crates-io mirror
//! only carries a small vendored set (`xla`, `anyhow`, `thiserror`,
//! `log`, ...). `rand`, `serde`, `proptest` and `criterion` are therefore
//! re-implemented here at the scale this project needs.
pub mod backoff;
pub mod blob;
pub mod channel;
pub mod json;
pub mod logging;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;

/// Format a float with engineering-style precision used across reports.
pub fn fmt_sig(v: f64, digits: usize) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let mag = v.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", dec.min(6), v)
}

/// Clamp helper for f64 (std's `clamp` panics on NaN bounds; ours is total).
pub fn clampf(v: f64, lo: f64, hi: f64) -> f64 {
    if v < lo {
        lo
    } else if v > hi {
        hi
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_sig_basic() {
        assert_eq!(fmt_sig(0.0, 3), "0");
        assert_eq!(fmt_sig(1234.0, 3), "1234");
        assert_eq!(fmt_sig(0.012345, 3), "0.0123");
    }

    #[test]
    fn clampf_total() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }

}
