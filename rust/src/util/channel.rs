//! Bounded multi-producer/multi-consumer channel on the [`util::sync`]
//! shim, so the actor→learner protocol in `coordinator::actor_learner`
//! is model-checkable under loom (`tests/loom_models.rs` explores the
//! send/recv/close lifecycle on these exact types).
//!
//! Semantics, chosen for the async search pipeline:
//!
//! - **Bounded + blocking.** `send` blocks while the queue is at
//!   capacity — backpressure from slow learners propagates to actors
//!   instead of growing an unbounded replay backlog.
//! - **FIFO.** Receivers observe messages in send order. Combined with
//!   the actors' in-order per-seed sends, this is what lets learners
//!   wait on "episode k of seed s" without deadlock.
//! - **Close = last sender gone.** `recv` drains whatever was accepted,
//!   then reports [`RecvError`] exactly once per receiver; a message
//!   accepted by `send` is never dropped by shutdown. `send` fails with
//!   the value handed back once every receiver is gone.
//!
//! One mutex guards the queue and both endpoint counts; one condvar
//! (always `notify_all`) covers both the not-full and not-empty
//! conditions. Two condvars would wake fewer threads, but a single one
//! keeps the protocol inside what the vendored loom explorer models
//! faithfully, and channel critical sections are a push/pop — contention
//! is not the bottleneck next to an SAC update.
//!
//! [`util::sync`]: super::sync

use std::collections::VecDeque;

use super::sync::{Arc, Condvar, Mutex};

/// The value could not be delivered: every [`Receiver`] has been
/// dropped. The undelivered message is handed back.
#[derive(Debug)]
pub struct SendError<T>(pub T);

impl<T> std::fmt::Display for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "channel send failed: all receivers dropped")
    }
}

/// The channel is closed (every [`Sender`] dropped) and fully drained.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "channel recv failed: closed and drained")
    }
}

struct Inner<T> {
    queue: VecDeque<T>,
    cap: usize,
    senders: usize,
    receivers: usize,
}

struct Shared<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

/// Sending half of a [`bounded`] channel. Clone freely — one per actor.
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half of a [`bounded`] channel. Clone freely — one per
/// learner; each accepted message is observed by exactly one receiver.
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Create a bounded FIFO channel with room for `cap` in-flight messages
/// (`cap` is clamped to at least 1, like `WorkPool::new`).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner {
            queue: VecDeque::new(),
            cap: cap.max(1),
            senders: 1,
            receivers: 1,
        }),
        cv: Condvar::new(),
    });
    (Sender { shared: Arc::clone(&shared) }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Deliver `value`, blocking while the channel is full. Fails only
    /// when every receiver is gone, handing the value back; a returned
    /// `Ok` means some receiver will observe the message (or it is
    /// drained at close — accepted messages are never dropped).
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut inner = self.shared.inner.lock();
        loop {
            if inner.receivers == 0 {
                return Err(SendError(value));
            }
            if inner.queue.len() < inner.cap {
                inner.queue.push_back(value);
                drop(inner);
                self.shared.cv.notify_all();
                return Ok(());
            }
            inner = self.shared.cv.wait(inner);
        }
    }
}

impl<T> Receiver<T> {
    /// Take the oldest message, blocking while the channel is empty.
    /// Fails once the channel is closed (all senders dropped) *and*
    /// drained, so shutdown loses nothing that `send` accepted.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut inner = self.shared.inner.lock();
        loop {
            if let Some(v) = inner.queue.pop_front() {
                drop(inner);
                self.shared.cv.notify_all();
                return Ok(v);
            }
            if inner.senders == 0 {
                return Err(RecvError);
            }
            inner = self.shared.cv.wait(inner);
        }
    }

    /// Messages currently queued (diagnostic; racy by nature).
    pub fn len(&self) -> usize {
        self.shared.inner.lock().queue.len()
    }

    /// Whether the queue is currently empty (diagnostic; racy by nature).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Sender<T> {
        self.shared.inner.lock().senders += 1;
        Sender { shared: Arc::clone(&self.shared) }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Receiver<T> {
        self.shared.inner.lock().receivers += 1;
        Receiver { shared: Arc::clone(&self.shared) }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut inner = self.shared.inner.lock();
        inner.senders -= 1;
        let closed = inner.senders == 0;
        drop(inner);
        if closed {
            // Wake receivers parked on an empty queue so they observe
            // the close instead of sleeping forever.
            self.shared.cv.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut inner = self.shared.inner.lock();
        inner.receivers -= 1;
        let orphaned = inner.receivers == 0;
        drop(inner);
        if orphaned {
            // Wake senders parked on a full queue so they fail fast.
            self.shared.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::thread;

    #[test]
    fn fifo_order_within_capacity() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv().ok()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_blocks_on_full_until_a_recv_frees_a_slot() {
        let (tx, rx) = bounded(1);
        tx.send(1u32).unwrap();
        let h = thread::spawn(move || {
            // Blocks until the main thread pops the first message.
            tx.send(2u32).unwrap();
        });
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        h.join().unwrap();
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn close_drains_accepted_messages_then_errors() {
        let (tx, rx) = bounded(4);
        tx.send("a").unwrap();
        tx.send("b").unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), "a");
        assert_eq!(rx.recv().unwrap(), "b");
        assert_eq!(rx.recv(), Err(RecvError));
        // The close is sticky: every subsequent recv fails too.
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_fails_with_value_once_all_receivers_are_gone() {
        let (tx, rx) = bounded(2);
        drop(rx);
        let err = tx.send(41u64).unwrap_err();
        assert_eq!(err.0, 41);
    }

    #[test]
    fn sender_parked_on_full_queue_errors_when_receiver_drops() {
        let (tx, rx) = bounded(1);
        tx.send(0u8).unwrap();
        let h = thread::spawn(move || tx.send(1u8));
        // Give the sender a moment to park on the full queue, then
        // drop the only receiver; the parked send must fail, not hang.
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(rx);
        let err = h.join().unwrap().unwrap_err();
        assert_eq!(err.0, 1);
    }

    #[test]
    fn mpmc_delivers_every_message_exactly_once() {
        const SENDERS: usize = 4;
        const RECEIVERS: usize = 3;
        const PER_SENDER: usize = 100;
        let (tx, rx) = bounded(8);
        let mut producers = Vec::new();
        for s in 0..SENDERS {
            let tx = tx.clone();
            producers.push(thread::spawn(move || {
                for i in 0..PER_SENDER {
                    tx.send(s * PER_SENDER + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut consumers = Vec::new();
        for _ in 0..RECEIVERS {
            let rx = rx.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<usize> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        let expect: Vec<usize> = (0..SENDERS * PER_SENDER).collect();
        assert_eq!(all, expect, "every message observed by exactly one receiver");
    }
}
