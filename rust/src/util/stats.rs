//! Small statistics helpers used by reports, benches and tests.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean (inputs must be positive); used for the paper's
/// "averaged NX improvement" claims which are ratio averages.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Min/max that ignore NaN (returns 0 for empty).
pub fn minmax(xs: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        if x.is_nan() {
            continue;
        }
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if lo > hi {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

/// Exponential moving average over a series.
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

/// Running timing statistics for the bench harness.
#[derive(Debug, Default, Clone)]
pub struct Timing {
    pub samples_ns: Vec<f64>,
}

impl Timing {
    pub fn push(&mut self, ns: f64) {
        self.samples_ns.push(ns);
    }

    pub fn mean_ns(&self) -> f64 {
        mean(&self.samples_ns)
    }

    pub fn p50_ns(&self) -> f64 {
        percentile(&self.samples_ns, 50.0)
    }

    pub fn p99_ns(&self) -> f64 {
        percentile(&self.samples_ns, 99.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p99={}",
            self.samples_ns.len(),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p50_ns()),
            fmt_ns(self.p99_ns()),
        )
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_ratios() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[1.0, 1.0, 10.0], 0.5);
        assert_eq!(out[0], 1.0);
        assert_eq!(out[1], 1.0);
        assert!((out[2] - 5.5).abs() < 1e-12);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1_500.0), "1.50us");
        assert_eq!(fmt_ns(2_000_000.0), "2.00ms");
    }

    #[test]
    fn minmax_ignores_nan() {
        let (lo, hi) = minmax(&[3.0, f64::NAN, -1.0]);
        assert_eq!((lo, hi), (-1.0, 3.0));
    }
}
