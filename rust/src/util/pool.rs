//! A persistent bounded worker pool.
//!
//! The sweeps and the orchestrator originally spun up a fresh set of
//! scoped threads per batch of jobs (`run_pool` in
//! `coordinator::sweep`). That is fine for a one-shot CLI run, but the
//! `edc serve` daemon multiplexes *many concurrent orchestrations* over
//! the lifetime of one process — it needs a single pool whose worker
//! count bounds the machine-wide compute, with every job of every
//! orchestration flowing through the same queue. [`WorkPool`] is that
//! pool; `run_pool` is now a thin wrapper that builds a throwaway one.
//!
//! Semantics match the old scoped-thread pool exactly:
//!
//! - [`run_batch`](WorkPool::run_batch) preserves job order in its
//!   results;
//! - a job that panics yields `Err(panic message)` in its slot while the
//!   other jobs keep running (workers survive task panics);
//! - mutex/condvar poisoning is recovered (built into
//!   [`util::sync`](crate::util::sync)'s wrappers): the queue is pop-only
//!   and each result slot is written once, so the protected invariants
//!   hold at every panic point.
//!
//! All synchronization goes through [`crate::util::sync`], so under
//! `--cfg loom` the pool's enqueue/drain/shutdown protocol is explored by
//! `rust/tests/loom_models.rs`.
//!
//! One rule: **never call `run_batch` from inside a pool task.** The
//! caller blocks until its whole batch drains, so a task that submits
//! and waits on a nested batch can deadlock a saturated pool. Batch
//! callers are always dedicated driver threads (the CLI main thread, or
//! an `edc serve` job runner).

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{thread, Arc, Condvar, Mutex};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};

type Task = Box<dyn FnOnce() + Send + 'static>;

/// One batch job's write-once result cell.
type Slot<R> = Mutex<Option<Result<R, String>>>;

struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
    stop: AtomicBool,
}

/// Render a panic payload as a readable message (shared with the sweep's
/// failure reports).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    match payload.downcast::<&str>() {
        Ok(s) => (*s).to_string(),
        Err(payload) => match payload.downcast::<String>() {
            Ok(s) => *s,
            Err(_) => "worker panicked (non-string payload)".to_string(),
        },
    }
}

/// A fixed-size pool of worker threads consuming a shared task queue.
///
/// Dropping the pool initiates shutdown: workers finish every task
/// already queued (so an in-flight [`run_batch`](WorkPool::run_batch)
/// still completes), then exit and are joined.
pub struct WorkPool {
    shared: Arc<PoolShared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl WorkPool {
    /// Spawn a pool of `size.max(1)` workers.
    pub fn new(size: usize) -> WorkPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let workers = (0..size.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        WorkPool { shared, workers }
    }

    /// A pool sized to the machine (`available_parallelism`, min 1).
    pub fn machine_sized() -> WorkPool {
        let hw = std::thread::available_parallelism().map_or(4, |n| n.get());
        WorkPool::new(hw)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one task. Panics inside the task are contained (the
    /// worker survives); use [`run_batch`](WorkPool::run_batch) to
    /// observe results or failures.
    pub fn execute(&self, task: Task) {
        self.shared.queue.lock().push_back(task);
        self.shared.available.notify_one();
    }

    /// Deliberately poison the task-queue mutex. Test-only hook for the
    /// poison-recovery coverage (`tests/failure_injection.rs`, loom
    /// models).
    #[doc(hidden)]
    pub fn poison_queue_for_test(&self) {
        self.shared.queue.poison_for_test();
    }

    /// Run `jobs` through the pool and block until all of them finish,
    /// preserving job order in the results. A job that panics yields
    /// `Err(panic message)` in its slot; the rest keep running.
    ///
    /// Concurrent `run_batch` calls from different threads interleave
    /// their tasks in the shared queue — this is exactly how `edc serve`
    /// multiplexes orchestrations. Do not call from inside a pool task
    /// (see the module docs).
    pub fn run_batch<J, R, F>(&self, jobs: Vec<J>, f: F) -> Vec<Result<R, String>>
    where
        J: Send + 'static,
        R: Send + 'static,
        F: Fn(J) -> R + Send + Sync + 'static,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let slots: Arc<Vec<Slot<R>>> = Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let remaining = Arc::new((Mutex::new(n), Condvar::new()));
        for (idx, job) in jobs.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let slots = Arc::clone(&slots);
            let remaining = Arc::clone(&remaining);
            self.execute(Box::new(move || {
                let outcome = catch_unwind(AssertUnwindSafe(|| f(job))).map_err(panic_message);
                *slots[idx].lock() = Some(outcome);
                let (count, done) = &*remaining;
                let mut left = count.lock();
                *left -= 1;
                if *left == 0 {
                    done.notify_all();
                }
            }));
        }
        let (count, done) = &*remaining;
        let mut left = count.lock();
        while *left > 0 {
            left = done.wait(left);
        }
        drop(left);
        slots
            .iter()
            .map(|slot| {
                slot.lock().take().unwrap_or_else(|| {
                    Err("worker pool lost this job's result (worker died before writing it)"
                        .to_string())
                })
            })
            .collect()
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut q = shared.queue.lock();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.available.wait(q);
            }
        };
        let Some(task) = task else { break };
        let _ = catch_unwind(AssertUnwindSafe(task));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn batch_preserves_order_and_contains_panics() {
        let pool = WorkPool::new(3);
        let results = pool.run_batch(vec![1usize, 2, 3, 4, 5], |j| {
            if j == 3 {
                panic!("boom on {j}");
            }
            j * 10
        });
        assert_eq!(results.len(), 5);
        assert_eq!(results[0], Ok(10));
        assert_eq!(results[1], Ok(20));
        assert!(results[2].as_ref().unwrap_err().contains("boom on 3"));
        assert_eq!(results[3], Ok(40));
        assert_eq!(results[4], Ok(50));
        // Workers survived the panic: the pool still runs new batches.
        assert_eq!(pool.run_batch(vec![7usize], |j| j + 1), vec![Ok(8)]);
    }

    #[test]
    fn empty_batch_and_single_worker() {
        let pool = WorkPool::new(1);
        let empty: Vec<Result<u32, String>> = pool.run_batch(Vec::<u32>::new(), |j| j);
        assert!(empty.is_empty());
        assert_eq!(pool.size(), 1);
        assert_eq!(WorkPool::new(0).size(), 1, "zero-size pool clamps to one worker");
    }

    #[test]
    fn concurrent_batches_from_multiple_threads_interleave() {
        let pool = Arc::new(WorkPool::new(2));
        let ran = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for t in 0..3u64 {
                let pool = Arc::clone(&pool);
                let ran = Arc::clone(&ran);
                scope.spawn(move || {
                    let out = pool.run_batch((0..4u64).collect(), move |j| t * 100 + j);
                    assert_eq!(out.len(), 4);
                    for (j, r) in out.into_iter().enumerate() {
                        assert_eq!(r, Ok(t * 100 + j as u64));
                    }
                    ran.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(ran.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn drop_drains_queued_tasks() {
        let hit = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkPool::new(1);
            for _ in 0..8 {
                let hit = Arc::clone(&hit);
                pool.execute(Box::new(move || {
                    hit.fetch_add(1, Ordering::SeqCst);
                }));
            }
            // Drop: workers must finish everything already queued.
        }
        assert_eq!(hit.load(Ordering::SeqCst), 8);
    }
}
