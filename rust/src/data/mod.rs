//! Synthetic datasets (DESIGN.md §2 substitution for MNIST / CIFAR-10).
//!
//! The RL loop only needs a *learnable* classification task whose
//! accuracy responds to fine-tuning the way a real dataset's does. The
//! generators here produce deterministic, class-structured images:
//!
//! - [`synth_mnist`]: 28x28x1 stroke-rendered digit glyphs with random
//!   translation, scale jitter and pixel noise — LeNet-5 trained from
//!   scratch exceeds 95% accuracy on held-out samples.
//! - [`synth_cifar`]: 32x32x3 class-conditioned texture fields
//!   (per-class frequency/orientation signatures + color palette).

pub mod loader;
pub mod synth_cifar;
pub mod synth_mnist;

pub use loader::BatchIter;
pub use synth_cifar::synth_cifar;
pub use synth_mnist::synth_mnist;

/// A dataset: images flattened row-major [n, h, w, c] + int labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Dataset {
    pub fn image_elems(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Borrow image i as a slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.image_elems();
        &self.images[i * sz..(i + 1) * sz]
    }

    /// Split off the last `frac` as a held-out set.
    pub fn split(mut self, frac: f64) -> (Dataset, Dataset) {
        let n_test = ((self.n as f64) * frac).round() as usize;
        let n_train = self.n - n_test;
        let sz = self.image_elems();
        let test_images = self.images.split_off(n_train * sz);
        let test_labels = self.labels.split_off(n_train);
        let test = Dataset {
            images: test_images,
            labels: test_labels,
            n: n_test,
            h: self.h,
            w: self.w,
            c: self.c,
        };
        self.n = n_train;
        (self, test)
    }
}

/// Generate the dataset matching a network's artifact metadata.
pub fn for_network(name: &str, n: usize, seed: u64) -> Dataset {
    match name {
        "lenet5" => synth_mnist(n, seed),
        "vgg16_cifar" | "mobilenet_cifar" => synth_cifar(n, seed),
        other => panic!("no dataset generator for network '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_preserves_counts() {
        let d = synth_mnist(100, 0);
        let (train, test) = d.split(0.2);
        assert_eq!(train.n, 80);
        assert_eq!(test.n, 20);
        assert_eq!(train.images.len(), 80 * 28 * 28);
        assert_eq!(test.labels.len(), 20);
    }

    #[test]
    fn for_network_dispatch() {
        assert_eq!(for_network("lenet5", 10, 0).c, 1);
        assert_eq!(for_network("vgg16_cifar", 10, 0).c, 3);
    }
}
