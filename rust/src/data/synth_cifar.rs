//! Class-conditioned 32x32x3 texture images: each class owns a frequency/
//! orientation signature and a color palette; samples jitter the phase
//! and add noise. Learnable by small conv nets, deterministic by seed.

use super::Dataset;
use crate::util::rng::Rng;

/// Per-class texture parameters: (freq_x, freq_y, orientation-mix, rgb).
fn class_params(class: usize) -> (f32, f32, f32, [f32; 3]) {
    const PALETTE: [[f32; 3]; 10] = [
        [0.9, 0.2, 0.2],
        [0.2, 0.9, 0.2],
        [0.2, 0.3, 0.9],
        [0.9, 0.9, 0.2],
        [0.8, 0.3, 0.8],
        [0.2, 0.9, 0.9],
        [0.95, 0.6, 0.2],
        [0.5, 0.5, 0.9],
        [0.6, 0.9, 0.5],
        [0.9, 0.5, 0.6],
    ];
    let f = 1.0 + (class % 5) as f32;
    let o = (class as f32) * 0.314;
    (f, 1.0 + (class / 5) as f32 * 2.0, o, PALETTE[class % 10])
}

/// Generate `n` samples of 32x32x3 texture images, classes balanced.
pub fn synth_cifar(n: usize, seed: u64) -> Dataset {
    let (h, w, c) = (32usize, 32usize, 3usize);
    let mut rng = Rng::new(seed ^ 0xC1FA_10AD);
    let mut images = vec![0.0f32; n * h * w * c];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        labels.push(class as i32);
        let (fx, fy, orient, rgb) = class_params(class);
        let phase_x = rng.range(0.0, std::f64::consts::TAU) as f32;
        let phase_y = rng.range(0.0, std::f64::consts::TAU) as f32;
        let amp = rng.range(0.7, 1.0) as f32;
        let img = &mut images[i * h * w * c..(i + 1) * h * w * c];
        for y in 0..h {
            for x in 0..w {
                let xf = x as f32 / w as f32 * std::f32::consts::TAU;
                let yf = y as f32 / h as f32 * std::f32::consts::TAU;
                let u = xf * orient.cos() - yf * orient.sin();
                let v = xf * orient.sin() + yf * orient.cos();
                let t = amp * (0.5 + 0.5 * (fx * u + phase_x).sin() * (fy * v + phase_y).cos());
                for ch in 0..c {
                    let noise = rng.normal_ms(0.0, 0.04) as f32;
                    img[(y * w + x) * c + ch] = (t * rgb[ch] + noise).clamp(0.0, 1.0);
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let sz = h * w * c;
    let mut si = vec![0.0f32; n * sz];
    let mut sl = vec![0i32; n];
    for (dst, &src) in order.iter().enumerate() {
        si[dst * sz..(dst + 1) * sz].copy_from_slice(&images[src * sz..(src + 1) * sz]);
        sl[dst] = labels[src];
    }
    Dataset {
        images: si,
        labels: sl,
        n,
        h,
        w,
        c,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_balanced() {
        let a = synth_cifar(50, 9);
        let b = synth_cifar(50, 9);
        assert_eq!(a.images, b.images);
        let mut counts = [0usize; 10];
        for &l in &a.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 5));
    }

    #[test]
    fn rgb_channels_differ_by_class() {
        let d = synth_cifar(20, 4);
        // Class palettes must make channel means distinguishable between
        // at least two classes.
        let sz = d.image_elems();
        let mean_ch = |i: usize, ch: usize| -> f32 {
            let img = &d.images[i * sz..(i + 1) * sz];
            img.iter().skip(ch).step_by(3).sum::<f32>() / (32.0 * 32.0)
        };
        let mut found_diff = false;
        for i in 0..d.n {
            for j in 0..d.n {
                if d.labels[i] != d.labels[j]
                    && (mean_ch(i, 0) - mean_ch(j, 0)).abs() > 0.1
                {
                    found_diff = true;
                }
            }
        }
        assert!(found_diff);
    }
}
