//! Procedural digit glyphs: a stroke-skeleton per class rendered with
//! per-sample jitter. Deterministic given the seed.

use super::Dataset;
use crate::util::rng::Rng;

/// Stroke skeletons on a 7-segment-plus-diagonals grid in [0,1]^2.
/// Each stroke is (x0, y0, x1, y1).
fn glyph(class: usize) -> &'static [(f32, f32, f32, f32)] {
    match class {
        0 => &[
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
            (0.3, 0.8, 0.3, 0.2),
        ],
        1 => &[(0.5, 0.2, 0.5, 0.8), (0.4, 0.3, 0.5, 0.2)],
        2 => &[
            (0.3, 0.25, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.5),
            (0.7, 0.5, 0.3, 0.8),
            (0.3, 0.8, 0.7, 0.8),
        ],
        3 => &[
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.8),
            (0.4, 0.5, 0.7, 0.5),
            (0.3, 0.8, 0.7, 0.8),
        ],
        4 => &[
            (0.35, 0.2, 0.3, 0.55),
            (0.3, 0.55, 0.7, 0.55),
            (0.65, 0.2, 0.65, 0.8),
        ],
        5 => &[
            (0.7, 0.2, 0.3, 0.2),
            (0.3, 0.2, 0.3, 0.5),
            (0.3, 0.5, 0.7, 0.55),
            (0.7, 0.55, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
        ],
        6 => &[
            (0.65, 0.2, 0.35, 0.35),
            (0.35, 0.35, 0.3, 0.8),
            (0.3, 0.8, 0.7, 0.8),
            (0.7, 0.8, 0.7, 0.55),
            (0.7, 0.55, 0.3, 0.55),
        ],
        7 => &[(0.3, 0.2, 0.7, 0.2), (0.7, 0.2, 0.45, 0.8)],
        8 => &[
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
            (0.3, 0.8, 0.3, 0.2),
            (0.3, 0.5, 0.7, 0.5),
        ],
        _ => &[
            (0.7, 0.45, 0.3, 0.45),
            (0.3, 0.45, 0.3, 0.2),
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.8),
        ],
    }
}

/// Render a stroke with soft (Gaussian-falloff) thickness into `img`.
fn draw_stroke(img: &mut [f32], h: usize, w: usize, s: (f32, f32, f32, f32), thick: f32) {
    let (x0, y0, x1, y1) = s;
    let steps = 40;
    for i in 0..=steps {
        let t = i as f32 / steps as f32;
        let cx = (x0 + (x1 - x0) * t) * w as f32;
        let cy = (y0 + (y1 - y0) * t) * h as f32;
        let r = (thick * 2.5).ceil() as i32;
        for dy in -r..=r {
            for dx in -r..=r {
                let px = cx as i32 + dx;
                let py = cy as i32 + dy;
                if px < 0 || py < 0 || px >= w as i32 || py >= h as i32 {
                    continue;
                }
                let d2 = ((px as f32 - cx).powi(2) + (py as f32 - cy).powi(2)) / (thick * thick);
                let v = (-d2).exp();
                let idx = py as usize * w + px as usize;
                img[idx] = (img[idx] + v).min(1.0);
            }
        }
    }
}

/// Generate `n` samples of 28x28x1 digit images, classes balanced.
pub fn synth_mnist(n: usize, seed: u64) -> Dataset {
    let (h, w) = (28usize, 28usize);
    let mut rng = Rng::new(seed ^ 0x5EED_D161);
    let mut images = vec![0.0f32; n * h * w];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        labels.push(class as i32);
        let img = &mut images[i * h * w..(i + 1) * h * w];
        // Per-sample jitter: translation, scale, thickness, noise.
        let ox = rng.range(-0.08, 0.08) as f32;
        let oy = rng.range(-0.08, 0.08) as f32;
        let scale = rng.range(0.85, 1.15) as f32;
        let thick = rng.range(0.9, 1.6) as f32;
        for &(x0, y0, x1, y1) in glyph(class) {
            let tf = |x: f32, y: f32| {
                (
                    ((x - 0.5) * scale + 0.5 + ox).clamp(0.05, 0.95),
                    ((y - 0.5) * scale + 0.5 + oy).clamp(0.05, 0.95),
                )
            };
            let (ax, ay) = tf(x0, y0);
            let (bx, by) = tf(x1, y1);
            draw_stroke(img, h, w, (ax, ay, bx, by), thick);
        }
        for v in img.iter_mut() {
            *v += rng.normal_ms(0.0, 0.05) as f32;
            *v = v.clamp(0.0, 1.0);
        }
    }
    // Shuffle samples (keeping image/label pairing).
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let sz = h * w;
    let mut shuffled_images = vec![0.0f32; n * sz];
    let mut shuffled_labels = vec![0i32; n];
    for (dst, &src) in order.iter().enumerate() {
        shuffled_images[dst * sz..(dst + 1) * sz].copy_from_slice(&images[src * sz..(src + 1) * sz]);
        shuffled_labels[dst] = labels[src];
    }
    Dataset {
        images: shuffled_images,
        labels: shuffled_labels,
        n,
        h,
        w,
        c: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = synth_mnist(20, 7);
        let b = synth_mnist(20, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn balanced_classes() {
        let d = synth_mnist(100, 1);
        let mut counts = [0usize; 10];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn pixels_in_range_and_nonempty() {
        let d = synth_mnist(30, 2);
        assert!(d.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // Every image must have some ink.
        for i in 0..d.n {
            let ink: f32 = d.image(i).iter().sum();
            assert!(ink > 5.0, "image {i} nearly blank (ink {ink})");
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean intra-class L2 distance must be well below inter-class —
        // otherwise the task is unlearnable and fine-tune accuracy
        // would be meaningless.
        let d = synth_mnist(200, 3);
        let sz = d.image_elems();
        let mut by_class: Vec<Vec<&[f32]>> = vec![Vec::new(); 10];
        for i in 0..d.n {
            by_class[d.labels[i] as usize].push(&d.images[i * sz..(i + 1) * sz]);
        }
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut ni = 0;
        let mut nx = 0;
        for c in 0..10 {
            for i in 1..by_class[c].len().min(5) {
                intra += dist(by_class[c][0], by_class[c][i]);
                ni += 1;
            }
            let c2 = (c + 1) % 10;
            inter += dist(by_class[c][0], by_class[c2][0]);
            nx += 1;
        }
        let (intra, inter) = (intra / ni as f64, inter / nx as f64);
        assert!(
            inter > 1.5 * intra,
            "classes not separable: intra {intra} inter {inter}"
        );
    }
}
