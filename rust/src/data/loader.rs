//! Mini-batch iteration with per-epoch shuffling.

use super::Dataset;
use crate::util::rng::Rng;

/// Infinite batch iterator over a dataset (reshuffles each epoch).
pub struct BatchIter<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
    pub epochs: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64) -> BatchIter<'a> {
        assert!(batch > 0 && batch <= data.n, "batch {} vs n {}", batch, data.n);
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..data.n).collect();
        rng.shuffle(&mut order);
        BatchIter {
            data,
            batch,
            order,
            pos: 0,
            rng,
            epochs: 0,
        }
    }

    /// Next batch as (images [B*H*W*C], labels [B]).
    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        if self.pos + self.batch > self.data.n {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            self.epochs += 1;
        }
        let sz = self.data.image_elems();
        let mut images = Vec::with_capacity(self.batch * sz);
        let mut labels = Vec::with_capacity(self.batch);
        for i in 0..self.batch {
            let idx = self.order[self.pos + i];
            images.extend_from_slice(self.data.image(idx));
            labels.push(self.data.labels[idx]);
        }
        self.pos += self.batch;
        (images, labels)
    }

    /// Iterate the dataset once in fixed order (for eval), yielding full
    /// batches only (the tail partial batch is dropped, as the AOT graphs
    /// have a fixed batch dimension).
    pub fn eval_batches(data: &'a Dataset, batch: usize) -> Vec<(Vec<f32>, Vec<i32>)> {
        let sz = data.image_elems();
        let mut out = Vec::new();
        let mut i = 0;
        while i + batch <= data.n {
            let mut images = Vec::with_capacity(batch * sz);
            let mut labels = Vec::with_capacity(batch);
            for k in i..i + batch {
                images.extend_from_slice(data.image(k));
                labels.push(data.labels[k]);
            }
            out.push((images, labels));
            i += batch;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn batches_have_right_shapes() {
        let d = synth_mnist(50, 0);
        let mut it = BatchIter::new(&d, 16, 1);
        let (x, y) = it.next_batch();
        assert_eq!(x.len(), 16 * 28 * 28);
        assert_eq!(y.len(), 16);
    }

    #[test]
    fn epoch_rollover_reshuffles() {
        let d = synth_mnist(20, 0);
        let mut it = BatchIter::new(&d, 8, 1);
        let mut seen = 0;
        while it.epochs == 0 {
            it.next_batch();
            seen += 1;
            assert!(seen < 10, "epoch never rolled");
        }
        assert!(it.epochs >= 1);
    }

    #[test]
    fn eval_batches_cover_dataset_without_tail() {
        let d = synth_mnist(50, 0);
        let batches = BatchIter::eval_batches(&d, 16);
        assert_eq!(batches.len(), 3); // 48 of 50 samples
        for (x, y) in &batches {
            assert_eq!(x.len(), 16 * 28 * 28);
            assert_eq!(y.len(), 16);
        }
    }
}
