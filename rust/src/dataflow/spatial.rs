//! Spatial mapping: PE-array sizing, tiling and operand reuse factors.
//!
//! For a layer `L` and dataflow `A:B`, the accelerator instantiates a
//! `|A| x |B|` PE array (tiled down to `pe_cap` when the trip counts are
//! large — real arrays are bounded; the paper's per-layer area numbers
//! reflect each layer's own array). Reuse factors follow directly from
//! Algorithm 1's index sets:
//!
//! - operand `T` is **spatially reused** across every unrolled loop that
//!   does *not* index `T` (all PEs along that axis see the same value);
//! - output partial sums are **spatially reduced** across unrolled
//!   reduction loops (adder tree), halving result traffic.

use super::{Dataflow, LoopDim};
use crate::model::{LayerKind, LayerSpec};

/// Result of mapping one layer onto one dataflow.
#[derive(Clone, Copy, Debug)]
pub struct Mapping {
    /// Trip counts of the two unrolled loops (after depthwise adjustment).
    pub unroll_a: usize,
    pub unroll_b: usize,
    /// PE array actually instantiated (capped + tiled).
    pub pe_rows: usize,
    pub pe_cols: usize,
    /// Number of sequential tiles needed to cover the full unroll space.
    pub tiles: u64,
    /// Spatial reuse factors per operand (>= 1).
    pub reuse_input: f64,
    pub reuse_weight: f64,
    pub reuse_output: f64,
    /// Spatial reduction factor for partial sums (>= 1).
    pub reduction: f64,
    /// Fraction of PEs doing useful work in the steady state (<= 1).
    pub utilization: f64,
}

impl Mapping {
    pub fn pes(&self) -> u64 {
        (self.pe_rows as u64) * (self.pe_cols as u64)
    }
}

/// Hardware bound on the PE array (both axes). The paper sizes each
/// dataflow's array to the layer (`A·B` PEs); we keep that behaviour by
/// default but cap at `pe_cap` per axis to keep CI:CO on 4096-wide FC
/// layers physical (matches the paper's blow-up in Table 4 area).
pub const DEFAULT_PE_CAP: usize = 4096;

/// Compute the mapping of `layer` under `df`.
pub fn map_layer(layer: &LayerSpec, df: Dataflow, pe_cap: usize) -> Mapping {
    let trip = |d: LoopDim| -> usize {
        let t = effective_trip(layer, d);
        t.max(1)
    };
    let ta = trip(df.a);
    let tb = trip(df.b);

    let pe_rows = ta.min(pe_cap);
    let pe_cols = tb.min(pe_cap);
    let tiles_a = ta.div_ceil(pe_rows) as u64;
    let tiles_b = tb.div_ceil(pe_cols) as u64;

    // Utilization: ragged final tiles leave PEs idle.
    let util_a = ta as f64 / (tiles_a as f64 * pe_rows as f64);
    let util_b = tb as f64 / (tiles_b as f64 * pe_cols as f64);

    let reuse = |indexes: fn(LoopDim) -> bool| -> f64 {
        let mut r = 1.0;
        if !indexes(df.a) {
            r *= pe_rows as f64;
        }
        if !indexes(df.b) {
            r *= pe_cols as f64;
        }
        r
    };
    let mut reduction = 1.0;
    if df.a.is_reduction() {
        reduction *= pe_rows as f64;
    }
    if df.b.is_reduction() {
        reduction *= pe_cols as f64;
    }

    Mapping {
        unroll_a: ta,
        unroll_b: tb,
        pe_rows,
        pe_cols,
        tiles: tiles_a * tiles_b,
        reuse_input: reuse(LoopDim::indexes_input),
        reuse_weight: reuse(LoopDim::indexes_weight),
        reuse_output: reuse(LoopDim::indexes_output),
        reduction,
        utilization: util_a * util_b,
    }
}

/// Depthwise conv has a single input channel per group, so `CI`-unrolling
/// degenerates to 1; dense layers have unit spatial/filter loops.
fn effective_trip(layer: &LayerSpec, d: LoopDim) -> usize {
    match (layer.kind, d) {
        (LayerKind::DepthwiseConv, LoopDim::Ci) => 1,
        _ => layer.trip(d),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn conv_layer() -> LayerSpec {
        // conv2 of LeNet-5: CO=50, CI=20, X=Y=8, FX=FY=5.
        zoo::lenet5().layers[2].clone()
    }

    #[test]
    fn xy_reuses_weights_spatially() {
        // X:Y unrolls the two output-pixel loops. Weights are not indexed
        // by x or y, so every PE shares the same weight: reuse = 8*8.
        let m = map_layer(&conv_layer(), Dataflow::XY, DEFAULT_PE_CAP);
        assert_eq!((m.pe_rows, m.pe_cols), (8, 8));
        assert_eq!(m.reuse_weight, 64.0);
        assert_eq!(m.reuse_output, 1.0); // outputs all distinct
        assert_eq!(m.reduction, 1.0); // no reduction loops unrolled
    }

    #[test]
    fn fxfy_accumulates_spatially() {
        // FX:FY unrolls the filter loops: both are reduction loops, so
        // partial sums collapse through a 5x5 adder tree.
        let m = map_layer(&conv_layer(), Dataflow::FXFY, DEFAULT_PE_CAP);
        assert_eq!((m.pe_rows, m.pe_cols), (5, 5));
        assert_eq!(m.reduction, 25.0);
        assert_eq!(m.reuse_output, 25.0); // O not indexed by fx/fy
        assert_eq!(m.reuse_weight, 1.0);
        assert_eq!(m.reuse_input, 1.0);
    }

    #[test]
    fn cico_reuses_inputs_co_times() {
        // CI:CO: inputs not indexed by co -> reused CO times; weights all
        // distinct; partial sums reduced CI-ways. Matches paper §3 prose.
        let m = map_layer(&conv_layer(), Dataflow::CICO, DEFAULT_PE_CAP);
        assert_eq!(m.reuse_input, 50.0); // CO = 50
        assert_eq!(m.reuse_weight, 1.0);
        assert_eq!(m.reduction, 20.0); // CI = 20
    }

    #[test]
    fn xfx_mixed_reuse() {
        // X:FX: weights not indexed by x -> reused X times; outputs not
        // indexed by fx -> reduced FX-ways.
        let m = map_layer(&conv_layer(), Dataflow::XFX, DEFAULT_PE_CAP);
        assert_eq!(m.reuse_weight, 8.0); // X = 8
        assert_eq!(m.reduction, 5.0); // FX = 5
    }

    #[test]
    fn pe_cap_tiles_large_layers() {
        let net = zoo::vgg16();
        let fc6 = net.layers.iter().find(|l| l.name == "fc6").unwrap();
        // CI:CO on fc6: 25088 x 4096 -> capped at 4096 per axis.
        let m = map_layer(fc6, Dataflow::CICO, DEFAULT_PE_CAP);
        assert!(m.pe_rows <= DEFAULT_PE_CAP && m.pe_cols <= DEFAULT_PE_CAP);
        assert!(m.tiles > 1);
        assert!(m.utilization > 0.0 && m.utilization <= 1.0);
    }

    #[test]
    fn depthwise_ci_degenerates() {
        let net = zoo::mobilenet_v1();
        let dw = net
            .layers
            .iter()
            .find(|l| l.kind == LayerKind::DepthwiseConv)
            .unwrap();
        let m = map_layer(dw, Dataflow::CICO, DEFAULT_PE_CAP);
        // CI axis is 1 (depthwise): array collapses to a column.
        assert!(m.pe_rows == 1 || m.pe_cols == 1);
    }

    #[test]
    fn dense_layers_have_unit_spatial_loops() {
        let net = zoo::lenet5();
        let fc1 = net.layers.iter().find(|l| l.name == "fc1").unwrap();
        let m = map_layer(fc1, Dataflow::XY, DEFAULT_PE_CAP);
        assert_eq!((m.pe_rows, m.pe_cols), (1, 1));
        assert_eq!(m.pes(), 1);
    }

    #[test]
    fn utilization_bounds_for_all_dataflows() {
        let net = zoo::vgg16_cifar();
        for df in Dataflow::all_fifteen() {
            for l in net.layers.iter().filter(|l| l.is_compute()) {
                let m = map_layer(l, df, DEFAULT_PE_CAP);
                assert!(
                    m.utilization > 0.0 && m.utilization <= 1.0 + 1e-12,
                    "{} {} util {}",
                    df.label(),
                    l.name,
                    m.utilization
                );
                assert!(m.reuse_input >= 1.0 && m.reuse_weight >= 1.0);
            }
        }
    }
}
