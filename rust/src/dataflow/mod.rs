//! Dataflow taxonomy and spatial-reuse analysis (paper §3, Table 1).
//!
//! A *dataflow* `A:B` unrolls two of the six loops of Algorithm 1 across a
//! 2-D array of processing elements. Which loops are unrolled decides how
//! often each operand (input feature map `I`, weights `W`, partial sums
//! `O`) must travel between SRAM and the array — the dominant energy term.
//!
//! The analysis here is generic over all C(6,2) = 15 loop pairs; the four
//! dataflows the paper evaluates (`X:Y`, `FX:FY`, `X:FX`, `CI:CO`) are
//! surfaced as constants.

pub mod spatial;

/// The six loops of a convolutional layer (Algorithm 1 of the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LoopDim {
    Co,
    Ci,
    X,
    Y,
    Fx,
    Fy,
}

impl LoopDim {
    pub const ALL: [LoopDim; 6] = [
        LoopDim::Co,
        LoopDim::Ci,
        LoopDim::X,
        LoopDim::Y,
        LoopDim::Fx,
        LoopDim::Fy,
    ];

    pub fn label(self) -> &'static str {
        match self {
            LoopDim::Co => "CO",
            LoopDim::Ci => "CI",
            LoopDim::X => "X",
            LoopDim::Y => "Y",
            LoopDim::Fx => "FX",
            LoopDim::Fy => "FY",
        }
    }

    /// Does the input feature map `I[ci][x+fx][y+fy]` vary along this loop?
    pub fn indexes_input(self) -> bool {
        !matches!(self, LoopDim::Co)
    }

    /// Does the weight tensor `W[co][ci][fx][fy]` vary along this loop?
    pub fn indexes_weight(self) -> bool {
        !matches!(self, LoopDim::X | LoopDim::Y)
    }

    /// Does the output `O[co][x][y]` vary along this loop?
    pub fn indexes_output(self) -> bool {
        matches!(self, LoopDim::Co | LoopDim::X | LoopDim::Y)
    }

    /// Is this a reduction loop (accumulated into the same output)?
    pub fn is_reduction(self) -> bool {
        !self.indexes_output()
    }
}

/// A dataflow: the (unordered) pair of spatially-unrolled loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Dataflow {
    pub a: LoopDim,
    pub b: LoopDim,
}

impl Dataflow {
    pub fn new(a: LoopDim, b: LoopDim) -> Dataflow {
        assert_ne!(a, b, "dataflow must unroll two distinct loops");
        // Canonical order for Eq/Hash stability.
        if a <= b {
            Dataflow { a, b }
        } else {
            Dataflow { a: b, b: a }
        }
    }

    /// The four dataflows of the paper's evaluation (Table 1).
    pub const XY: Dataflow = Dataflow {
        a: LoopDim::X,
        b: LoopDim::Y,
    };
    pub const FXFY: Dataflow = Dataflow {
        a: LoopDim::Fx,
        b: LoopDim::Fy,
    };
    pub const XFX: Dataflow = Dataflow {
        a: LoopDim::X,
        b: LoopDim::Fx,
    };
    pub const CICO: Dataflow = Dataflow {
        a: LoopDim::Co,
        b: LoopDim::Ci,
    };

    /// The paper's four evaluated dataflows, in table order.
    pub fn paper_four() -> [Dataflow; 4] {
        [Self::XY, Self::FXFY, Self::XFX, Self::CICO]
    }

    /// All 15 loop pairs (paper §3: "there are C(6,2)=15 possibilities").
    pub fn all_fifteen() -> Vec<Dataflow> {
        let mut out = Vec::with_capacity(15);
        for i in 0..LoopDim::ALL.len() {
            for j in (i + 1)..LoopDim::ALL.len() {
                out.push(Dataflow::new(LoopDim::ALL[i], LoopDim::ALL[j]));
            }
        }
        out
    }

    /// Human-readable `A:B` label matching the paper's notation.
    pub fn label(&self) -> String {
        // Paper prints e.g. "X:Y", "FX:FY", "X:FX", "CI:CO".
        let order = [
            LoopDim::X,
            LoopDim::Y,
            LoopDim::Fx,
            LoopDim::Fy,
            LoopDim::Ci,
            LoopDim::Co,
        ];
        let pos = |d: LoopDim| order.iter().position(|&o| o == d).unwrap();
        let (first, second) = if pos(self.a) <= pos(self.b) {
            (self.a, self.b)
        } else {
            (self.b, self.a)
        };
        format!("{}:{}", first.label(), second.label())
    }

    /// Parse "X:Y"-style labels (case-insensitive).
    pub fn parse(s: &str) -> Option<Dataflow> {
        let up = s.to_uppercase();
        let mut parts = up.split(':');
        let pa = parse_dim(parts.next()?)?;
        let pb = parse_dim(parts.next()?)?;
        if parts.next().is_some() || pa == pb {
            return None;
        }
        Some(Dataflow::new(pa, pb))
    }

    /// Parse the CLI/serve-protocol dataflow selector: `paper` (the four
    /// evaluated dataflows in table order), `all` (all 15 loop pairs), or
    /// a comma-separated label list like `X:Y,CI:CO`. Errors name the
    /// offending token.
    ///
    /// # Examples
    ///
    /// ```
    /// use edcompress::dataflow::Dataflow;
    ///
    /// assert_eq!(Dataflow::parse_list("paper").unwrap().len(), 4);
    /// assert_eq!(Dataflow::parse_list("all").unwrap().len(), 15);
    /// assert_eq!(
    ///     Dataflow::parse_list("X:Y, fx:fy").unwrap(),
    ///     vec![Dataflow::XY, Dataflow::FXFY],
    /// );
    /// assert!(Dataflow::parse_list("Q:R").unwrap_err().contains("Q:R"));
    /// ```
    pub fn parse_list(arg: &str) -> Result<Vec<Dataflow>, String> {
        match arg {
            "paper" => Ok(Self::paper_four().to_vec()),
            "all" => Ok(Self::all_fifteen()),
            list => list
                .split(',')
                .map(|s| {
                    let s = s.trim();
                    Dataflow::parse(s).ok_or_else(|| format!("unknown dataflow '{s}'"))
                })
                .collect(),
        }
    }

    pub fn dims(&self) -> [LoopDim; 2] {
        [self.a, self.b]
    }
}

fn parse_dim(s: &str) -> Option<LoopDim> {
    match s.trim() {
        "CO" => Some(LoopDim::Co),
        "CI" => Some(LoopDim::Ci),
        "X" => Some(LoopDim::X),
        "Y" => Some(LoopDim::Y),
        "FX" => Some(LoopDim::Fx),
        "FY" => Some(LoopDim::Fy),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifteen_distinct_dataflows() {
        let all = Dataflow::all_fifteen();
        assert_eq!(all.len(), 15);
        let mut set = std::collections::HashSet::new();
        for df in &all {
            assert!(set.insert(*df), "duplicate {df:?}");
        }
    }

    #[test]
    fn labels_match_paper_notation() {
        assert_eq!(Dataflow::XY.label(), "X:Y");
        assert_eq!(Dataflow::FXFY.label(), "FX:FY");
        assert_eq!(Dataflow::XFX.label(), "X:FX");
        assert_eq!(Dataflow::CICO.label(), "CI:CO");
    }

    #[test]
    fn parse_roundtrip() {
        for df in Dataflow::all_fifteen() {
            assert_eq!(Dataflow::parse(&df.label()), Some(df));
        }
        assert_eq!(Dataflow::parse("ci:co"), Some(Dataflow::CICO));
        assert_eq!(Dataflow::parse("X:X"), None);
        assert_eq!(Dataflow::parse("bogus"), None);
    }

    #[test]
    fn index_sets_match_algorithm1() {
        // I[ci][x+fx][y+fy]: varies with everything except co.
        assert!(!LoopDim::Co.indexes_input());
        assert!(LoopDim::Fx.indexes_input());
        // W[co][ci][fx][fy]: fixed along x, y.
        assert!(!LoopDim::X.indexes_weight());
        assert!(!LoopDim::Y.indexes_weight());
        assert!(LoopDim::Co.indexes_weight());
        // O[co][x][y]: reduction loops are ci, fx, fy.
        assert!(LoopDim::Ci.is_reduction());
        assert!(LoopDim::Fx.is_reduction());
        assert!(LoopDim::Fy.is_reduction());
        assert!(!LoopDim::X.is_reduction());
    }

    #[test]
    fn canonical_ordering() {
        let d1 = Dataflow::new(LoopDim::Y, LoopDim::X);
        let d2 = Dataflow::new(LoopDim::X, LoopDim::Y);
        assert_eq!(d1, d2);
    }
}
