//! Dense (fully-connected) layer with explicit forward/backward.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// `y = x @ w + b` with `x: [B, in]`, `w: [in, out]`, `b: [1, out]`.
#[derive(Clone, Debug)]
pub struct Linear {
    pub w: Tensor,
    pub b: Tensor,
}

/// Gradients for one linear layer.
#[derive(Clone, Debug)]
pub struct LinearGrads {
    pub dw: Tensor,
    pub db: Tensor,
}

impl Linear {
    /// He-style init scaled for the fan-in (good for ReLU nets; fine for
    /// tanh at the widths we use).
    pub fn new(fan_in: usize, fan_out: usize, rng: &mut Rng) -> Linear {
        let std = (2.0 / fan_in as f64).sqrt();
        Linear {
            w: Tensor::randn(&[fan_in, fan_out], std, rng),
            b: Tensor::zeros(&[1, fan_out]),
        }
    }

    pub fn fan_in(&self) -> usize {
        self.w.rows()
    }

    pub fn fan_out(&self) -> usize {
        self.w.cols()
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        x.matmul(&self.w).add_row(&self.b)
    }

    /// Workspace form of [`Linear::forward`]: `y = x @ w + b` written into
    /// a caller-owned `[B, out]` tensor. Bit-identical for finite inputs
    /// (see the `tensor` module docs), allocation-free.
    pub fn forward_into(&self, x: &Tensor, y: &mut Tensor) {
        x.matmul_into(&self.w, y);
        y.add_row_into(&self.b);
    }

    /// Backward pass. `x` is the layer input from the forward pass and
    /// `dy` the gradient flowing in from above; returns `dx` plus the
    /// parameter gradients.
    pub fn backward(&self, x: &Tensor, dy: &Tensor) -> (Tensor, LinearGrads) {
        let dw = x.matmul_tn(dy); // [in, out] = x^T @ dy
        let db = dy.sum_rows(); // [1, out]
        let dx = dy.matmul_nt(&self.w); // [B, in] = dy @ w^T
        (dx, LinearGrads { dw, db })
    }

    /// Workspace form of [`Linear::backward`]: writes `dw`/`db` into
    /// `grads` and, when `dx` is `Some`, the input gradient into it. The
    /// bottom layer of a critic update passes `None` and skips the `dx`
    /// GEMM outright — the allocating path always paid it.
    pub fn backward_into(
        &self,
        x: &Tensor,
        dy: &Tensor,
        grads: &mut LinearGrads,
        dx: Option<&mut Tensor>,
    ) {
        x.matmul_tn_into(dy, &mut grads.dw);
        dy.sum_rows_into(&mut grads.db);
        if let Some(dx) = dx {
            dy.matmul_nt_into(&self.w, dx);
        }
    }

    /// Input gradient only (`dx = dy @ wᵀ`): backprop *through* the layer
    /// without touching parameter gradients (the actor update
    /// differentiates through the Q nets wrt the action input alone).
    pub fn backward_input_into(&self, dy: &Tensor, dx: &mut Tensor) {
        dy.matmul_nt_into(&self.w, dx);
    }

    /// Flat parameter views for the optimizer.
    pub fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.w, &mut self.b]
    }

    pub fn params(&self) -> Vec<&Tensor> {
        vec![&self.w, &self.b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference check of dw, db, dx for a scalar loss L = sum(y).
    #[test]
    fn gradcheck_linear() {
        let mut rng = Rng::new(99);
        let layer = Linear::new(4, 3, &mut rng);
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let dy = Tensor::full(&[2, 3], 1.0); // dL/dy for L = sum(y)
        let (dx, grads) = layer.backward(&x, &dy);

        let eps = 1e-3f32;
        // dw check
        for idx in 0..layer.w.len() {
            let mut lp = layer.clone();
            lp.w.data_mut()[idx] += eps;
            let mut lm = layer.clone();
            lm.w.data_mut()[idx] -= eps;
            let fd = (lp.forward(&x).sum() - lm.forward(&x).sum()) / (2.0 * eps as f64);
            let an = grads.dw.data()[idx] as f64;
            assert!((fd - an).abs() < 1e-2, "dw[{idx}]: fd={fd} an={an}");
        }
        // db check
        for idx in 0..layer.b.len() {
            let mut lp = layer.clone();
            lp.b.data_mut()[idx] += eps;
            let mut lm = layer.clone();
            lm.b.data_mut()[idx] -= eps;
            let fd = (lp.forward(&x).sum() - lm.forward(&x).sum()) / (2.0 * eps as f64);
            let an = grads.db.data()[idx] as f64;
            assert!((fd - an).abs() < 1e-2, "db[{idx}]: fd={fd} an={an}");
        }
        // dx check
        for idx in 0..x.len() {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let fd = (layer.forward(&xp).sum() - layer.forward(&xm).sum()) / (2.0 * eps as f64);
            let an = dx.data()[idx] as f64;
            assert!((fd - an).abs() < 1e-2, "dx[{idx}]: fd={fd} an={an}");
        }
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let layer = Linear::new(8, 5, &mut rng);
        let x = Tensor::zeros(&[3, 8]);
        assert_eq!(layer.forward(&x).shape(), &[3, 5]);
    }
}
