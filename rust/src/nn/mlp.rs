//! Multi-layer perceptron with cached-forward / explicit-backward.

use super::linear::{Linear, LinearGrads};
use super::Activation;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// MLP: `n` hidden layers with activation, then a linear head.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Linear>,
    pub act: Activation,
}

/// Forward-pass cache: the input and every post-activation (plus the
/// pre-activation head output) needed for backprop.
pub struct MlpCache {
    /// inputs[i] is the input fed to layers[i].
    pub inputs: Vec<Tensor>,
    /// Final output (linear head, no activation).
    pub output: Tensor,
}

/// Per-layer parameter gradients.
pub struct MlpGrads {
    pub layers: Vec<LinearGrads>,
}

impl MlpGrads {
    pub fn zeros_like(mlp: &Mlp) -> MlpGrads {
        MlpGrads {
            layers: mlp
                .layers
                .iter()
                .map(|l| LinearGrads {
                    dw: Tensor::zeros(l.w.shape()),
                    db: Tensor::zeros(l.b.shape()),
                })
                .collect(),
        }
    }

    pub fn axpy(&mut self, alpha: f32, other: &MlpGrads) {
        for (a, b) in self.layers.iter_mut().zip(&other.layers) {
            a.dw.axpy(alpha, &b.dw);
            a.db.axpy(alpha, &b.db);
        }
    }

    pub fn scale(&mut self, s: f32) {
        for g in &mut self.layers {
            g.dw.scale(s);
            g.db.scale(s);
        }
    }

    /// Global gradient L2 norm — used for clipping.
    pub fn norm(&self) -> f64 {
        self.layers
            .iter()
            .map(|g| g.dw.sq_norm() + g.db.sq_norm())
            .sum::<f64>()
            .sqrt()
    }

    /// Clip to `max_norm` in place; returns the pre-clip norm.
    pub fn clip(&mut self, max_norm: f64) -> f64 {
        let n = self.norm();
        if n > max_norm && n > 0.0 {
            self.scale((max_norm / n) as f32);
        }
        n
    }

    pub fn tensors(&self) -> Vec<&Tensor> {
        self.layers
            .iter()
            .flat_map(|g| [&g.dw, &g.db])
            .collect()
    }
}

impl Mlp {
    /// `dims` = [in, h1, h2, ..., out].
    pub fn new(dims: &[usize], act: Activation, rng: &mut Rng) -> Mlp {
        assert!(dims.len() >= 2, "need at least in/out dims");
        let layers = dims
            .windows(2)
            .map(|w| Linear::new(w[0], w[1], rng))
            .collect();
        Mlp { layers, act }
    }

    pub fn in_dim(&self) -> usize {
        self.layers.first().unwrap().fan_in()
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().fan_out()
    }

    /// Plain forward (no cache) — for inference/eval.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        let last = self.layers.len() - 1;
        for (i, layer) in self.layers.iter().enumerate() {
            h = layer.forward(&h);
            if i != last {
                let act = self.act;
                h.map_inplace(|v| act.apply(v));
            }
        }
        h
    }

    /// Forward that records everything backward needs.
    pub fn forward_cached(&self, x: &Tensor) -> MlpCache {
        let mut inputs = Vec::with_capacity(self.layers.len());
        let mut h = x.clone();
        let last = self.layers.len() - 1;
        for (i, layer) in self.layers.iter().enumerate() {
            inputs.push(h.clone());
            h = layer.forward(&h);
            if i != last {
                let act = self.act;
                h.map_inplace(|v| act.apply(v));
            }
        }
        MlpCache { inputs, output: h }
    }

    /// Backward from `dout` (gradient wrt the head output). Returns the
    /// gradient wrt the network input along with parameter grads.
    pub fn backward(&self, cache: &MlpCache, dout: &Tensor) -> (Tensor, MlpGrads) {
        let mut grads: Vec<Option<LinearGrads>> = vec![None; self.layers.len()];
        let mut dy = dout.clone();
        let last = self.layers.len() - 1;
        for i in (0..self.layers.len()).rev() {
            if i != last {
                // dy currently is grad wrt post-activation of layer i;
                // convert to grad wrt pre-activation using the cached
                // *input of layer i+1* (== post-activation output of i).
                let post = &cache.inputs[i + 1];
                let act = self.act;
                let mut d = dy.clone();
                for (dv, &yv) in d.data_mut().iter_mut().zip(post.data()) {
                    *dv *= act.deriv_from_output(yv);
                }
                dy = d;
            }
            let (dx, g) = self.layers[i].backward(&cache.inputs[i], &dy);
            grads[i] = Some(g);
            dy = dx;
        }
        (
            dy,
            MlpGrads {
                layers: grads.into_iter().map(|g| g.unwrap()).collect(),
            },
        )
    }

    pub fn params_mut(&mut self) -> Vec<&mut Tensor> {
        self.layers
            .iter_mut()
            .flat_map(|l| l.params_mut())
            .collect()
    }

    pub fn params(&self) -> Vec<&Tensor> {
        self.layers.iter().flat_map(|l| l.params()).collect()
    }

    pub fn param_count(&self) -> usize {
        self.params().iter().map(|p| p.len()).sum()
    }

    /// Polyak soft update: self = (1-tau)*self + tau*src.
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        for (dst, s) in self.params_mut().into_iter().zip(src.params()) {
            dst.lerp_into(1.0 - tau, s, tau);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference gradient check through a 2-hidden-layer MLP for
    /// both parameter grads and input grads, with tanh and relu.
    #[test]
    fn gradcheck_mlp() {
        for act in [Activation::Tanh, Activation::Relu] {
            let mut rng = Rng::new(7);
            let mlp = Mlp::new(&[3, 8, 8, 2], act, &mut rng);
            let x = Tensor::randn(&[4, 3], 1.0, &mut rng);
            let cache = mlp.forward_cached(&x);
            // Loss = sum(output^2)/2 -> dout = output
            let dout = cache.output.clone();
            let (dx, grads) = mlp.backward(&cache, &dout);

            let loss = |m: &Mlp, xx: &Tensor| -> f64 {
                let y = m.forward(xx);
                y.data().iter().map(|&v| 0.5 * (v as f64) * (v as f64)).sum()
            };
            let eps = 1e-3f32;

            // Spot-check a handful of parameter coordinates in every layer.
            for (li, layer) in mlp.layers.iter().enumerate() {
                for idx in [0usize, layer.w.len() / 2, layer.w.len() - 1] {
                    let mut mp = mlp.clone();
                    mp.layers[li].w.data_mut()[idx] += eps;
                    let mut mm = mlp.clone();
                    mm.layers[li].w.data_mut()[idx] -= eps;
                    let fd = (loss(&mp, &x) - loss(&mm, &x)) / (2.0 * eps as f64);
                    let an = grads.layers[li].dw.data()[idx] as f64;
                    assert!(
                        (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                        "act {act:?} layer {li} w[{idx}]: fd={fd} an={an}"
                    );
                }
            }
            // Input gradient.
            for idx in 0..x.len() {
                let mut xp = x.clone();
                xp.data_mut()[idx] += eps;
                let mut xm = x.clone();
                xm.data_mut()[idx] -= eps;
                let fd = (loss(&mlp, &xp) - loss(&mlp, &xm)) / (2.0 * eps as f64);
                let an = dx.data()[idx] as f64;
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "act {act:?} dx[{idx}]: fd={fd} an={an}"
                );
            }
        }
    }

    #[test]
    fn soft_update_moves_toward_source() {
        let mut rng = Rng::new(3);
        let src = Mlp::new(&[2, 4, 1], Activation::Relu, &mut rng);
        let mut dst = Mlp::new(&[2, 4, 1], Activation::Relu, &mut rng);
        let before = dst.layers[0].w.at(0, 0);
        let target = src.layers[0].w.at(0, 0);
        dst.soft_update_from(&src, 0.5);
        let after = dst.layers[0].w.at(0, 0);
        assert!((after - (0.5 * before + 0.5 * target)).abs() < 1e-6);
    }

    #[test]
    fn grad_clip() {
        let mut rng = Rng::new(5);
        let mlp = Mlp::new(&[2, 4, 1], Activation::Tanh, &mut rng);
        let x = Tensor::randn(&[8, 2], 5.0, &mut rng);
        let cache = mlp.forward_cached(&x);
        let dout = Tensor::full(&[8, 1], 100.0);
        let (_, mut grads) = mlp.backward(&cache, &dout);
        grads.clip(1.0);
        assert!(grads.norm() <= 1.0 + 1e-4);
    }
}
