//! Multi-layer perceptron with cached-forward / explicit-backward.

use super::linear::{Linear, LinearGrads};
use super::Activation;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// MLP: `n` hidden layers with activation, then a linear head.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Linear>,
    pub act: Activation,
}

/// Forward-pass cache: the input and every post-activation (plus the
/// pre-activation head output) needed for backprop.
pub struct MlpCache {
    /// inputs[i] is the input fed to layers[i].
    pub inputs: Vec<Tensor>,
    /// Final output (linear head, no activation).
    pub output: Tensor,
}

impl MlpCache {
    /// Preallocate a cache for batch size `b`, for use with
    /// [`Mlp::forward_cached_into`] — the training loop owns one per
    /// network so the steady state never allocates.
    pub fn for_batch(mlp: &Mlp, b: usize) -> MlpCache {
        MlpCache {
            inputs: mlp
                .layers
                .iter()
                .map(|l| Tensor::zeros(&[b, l.fan_in()]))
                .collect(),
            output: Tensor::zeros(&[b, mlp.out_dim()]),
        }
    }
}

/// Caller-owned intermediate buffers for [`Mlp::backward_into`] /
/// [`Mlp::backward_input_into`]: one upstream-gradient buffer per layer
/// output. Reused across updates; sized once by [`MlpBackScratch::for_batch`].
pub struct MlpBackScratch {
    /// dys[i] holds the gradient flowing into layer i's output, [B, fan_out(i)].
    dys: Vec<Tensor>,
}

impl MlpBackScratch {
    /// Preallocate the per-layer gradient buffers for batch size `b`. One
    /// scratch can serve several networks of identical architecture (the
    /// twin critics and their targets share one).
    pub fn for_batch(mlp: &Mlp, b: usize) -> MlpBackScratch {
        MlpBackScratch {
            dys: mlp
                .layers
                .iter()
                .map(|l| Tensor::zeros(&[b, l.fan_out()]))
                .collect(),
        }
    }
}

/// `dy *= act'(post)` elementwise — converting a post-activation gradient
/// to a pre-activation one using the cached post-activation values.
fn scale_by_act_deriv(dy: &mut Tensor, post: &Tensor, act: Activation) {
    for (dv, &yv) in dy.data_mut().iter_mut().zip(post.data()) {
        *dv *= act.deriv_from_output(yv);
    }
}

/// Per-layer parameter gradients.
pub struct MlpGrads {
    pub layers: Vec<LinearGrads>,
}

impl MlpGrads {
    pub fn zeros_like(mlp: &Mlp) -> MlpGrads {
        MlpGrads {
            layers: mlp
                .layers
                .iter()
                .map(|l| LinearGrads {
                    dw: Tensor::zeros(l.w.shape()),
                    db: Tensor::zeros(l.b.shape()),
                })
                .collect(),
        }
    }

    pub fn axpy(&mut self, alpha: f32, other: &MlpGrads) {
        for (a, b) in self.layers.iter_mut().zip(&other.layers) {
            a.dw.axpy(alpha, &b.dw);
            a.db.axpy(alpha, &b.db);
        }
    }

    pub fn scale(&mut self, s: f32) {
        for g in &mut self.layers {
            g.dw.scale(s);
            g.db.scale(s);
        }
    }

    /// Global gradient L2 norm — used for clipping.
    pub fn norm(&self) -> f64 {
        self.layers
            .iter()
            .map(|g| g.dw.sq_norm() + g.db.sq_norm())
            .sum::<f64>()
            .sqrt()
    }

    /// Clip to `max_norm` in place; returns the pre-clip norm.
    pub fn clip(&mut self, max_norm: f64) -> f64 {
        let n = self.norm();
        if n > max_norm && n > 0.0 {
            self.scale((max_norm / n) as f32);
        }
        n
    }

    /// The gradient tensors in optimizer order, allocation-free (replaces
    /// the old `tensors() -> Vec<&Tensor>` round-trip; zip with
    /// [`Mlp::params_iter_mut`] for a fused [`Adam::step_pairs`](super::Adam::step_pairs)).
    pub fn iter(&self) -> impl Iterator<Item = &Tensor> + '_ {
        self.layers.iter().flat_map(|g| [&g.dw, &g.db])
    }
}

impl Mlp {
    /// `dims` = [in, h1, h2, ..., out].
    pub fn new(dims: &[usize], act: Activation, rng: &mut Rng) -> Mlp {
        assert!(dims.len() >= 2, "need at least in/out dims");
        let layers = dims
            .windows(2)
            .map(|w| Linear::new(w[0], w[1], rng))
            .collect();
        Mlp { layers, act }
    }

    pub fn in_dim(&self) -> usize {
        self.layers.first().unwrap().fan_in()
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().fan_out()
    }

    /// Plain forward (no cache) — for inference/eval.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        let last = self.layers.len() - 1;
        for (i, layer) in self.layers.iter().enumerate() {
            h = layer.forward(&h);
            if i != last {
                let act = self.act;
                h.map_inplace(|v| act.apply(v));
            }
        }
        h
    }

    /// Forward that records everything backward needs.
    pub fn forward_cached(&self, x: &Tensor) -> MlpCache {
        let mut inputs = Vec::with_capacity(self.layers.len());
        let mut h = x.clone();
        let last = self.layers.len() - 1;
        for (i, layer) in self.layers.iter().enumerate() {
            inputs.push(h.clone());
            h = layer.forward(&h);
            if i != last {
                let act = self.act;
                h.map_inplace(|v| act.apply(v));
            }
        }
        MlpCache { inputs, output: h }
    }

    /// Workspace form of [`Mlp::forward_cached`]: records the forward pass
    /// into a caller-owned, correctly-sized cache ([`MlpCache::for_batch`])
    /// without allocating. Bit-identical for finite inputs.
    pub fn forward_cached_into(&self, x: &Tensor, cache: &mut MlpCache) {
        let last = self.layers.len() - 1;
        cache.inputs[0].copy_from(x);
        for i in 0..self.layers.len() {
            let (head, tail) = cache.inputs.split_at_mut(i + 1);
            let dst = if i == last {
                &mut cache.output
            } else {
                &mut tail[0]
            };
            self.layers[i].forward_into(&head[i], dst);
            if i != last {
                let act = self.act;
                dst.map_inplace(|v| act.apply(v));
            }
        }
    }

    /// Backward from `dout` (gradient wrt the head output). Returns the
    /// gradient wrt the network input along with parameter grads.
    pub fn backward(&self, cache: &MlpCache, dout: &Tensor) -> (Tensor, MlpGrads) {
        let mut grads: Vec<Option<LinearGrads>> = vec![None; self.layers.len()];
        let mut dy = dout.clone();
        let last = self.layers.len() - 1;
        for i in (0..self.layers.len()).rev() {
            if i != last {
                // dy currently is grad wrt post-activation of layer i;
                // convert to grad wrt pre-activation using the cached
                // *input of layer i+1* (== post-activation output of i).
                scale_by_act_deriv(&mut dy, &cache.inputs[i + 1], self.act);
            }
            let (dx, g) = self.layers[i].backward(&cache.inputs[i], &dy);
            grads[i] = Some(g);
            dy = dx;
        }
        (
            dy,
            MlpGrads {
                layers: grads.into_iter().map(|g| g.unwrap()).collect(),
            },
        )
    }

    /// Workspace form of [`Mlp::backward`]: parameter gradients land in
    /// `grads`, intermediate upstream gradients in `scratch`, and the
    /// input gradient in `dx` when requested — passing `None` skips the
    /// bottom layer's `dx` GEMM entirely (a critic update never uses it).
    /// Bit-identical to [`Mlp::backward`] for finite inputs.
    pub fn backward_into(
        &self,
        cache: &MlpCache,
        dout: &Tensor,
        scratch: &mut MlpBackScratch,
        grads: &mut MlpGrads,
        mut dx: Option<&mut Tensor>,
    ) {
        let last = self.layers.len() - 1;
        scratch.dys[last].copy_from(dout);
        for i in (0..self.layers.len()).rev() {
            if i != last {
                scale_by_act_deriv(&mut scratch.dys[i], &cache.inputs[i + 1], self.act);
            }
            let (head, tail) = scratch.dys.split_at_mut(i);
            let dy = &tail[0];
            let dxi = if i > 0 {
                Some(&mut head[i - 1])
            } else {
                dx.as_deref_mut()
            };
            self.layers[i].backward_into(&cache.inputs[i], dy, &mut grads.layers[i], dxi);
        }
    }

    /// Backprop `dout` through the network computing **only** the input
    /// gradient — no parameter gradients. The actor update uses this to
    /// differentiate the policy loss through the (frozen-for-this-step) Q
    /// networks wrt the action input; the allocating path computed full
    /// `MlpGrads` there and threw them away.
    pub fn backward_input_into(
        &self,
        cache: &MlpCache,
        dout: &Tensor,
        scratch: &mut MlpBackScratch,
        dx: &mut Tensor,
    ) {
        let last = self.layers.len() - 1;
        scratch.dys[last].copy_from(dout);
        for i in (0..self.layers.len()).rev() {
            if i != last {
                scale_by_act_deriv(&mut scratch.dys[i], &cache.inputs[i + 1], self.act);
            }
            let (head, tail) = scratch.dys.split_at_mut(i);
            let dy = &tail[0];
            let dxi = if i > 0 {
                &mut head[i - 1]
            } else {
                &mut *dx
            };
            self.layers[i].backward_input_into(dy, dxi);
        }
    }

    pub fn params_mut(&mut self) -> Vec<&mut Tensor> {
        self.params_iter_mut().collect()
    }

    pub fn params(&self) -> Vec<&Tensor> {
        self.params_iter().collect()
    }

    /// Parameter tensors in optimizer order without the `Vec` round-trip.
    pub fn params_iter(&self) -> impl Iterator<Item = &Tensor> + '_ {
        self.layers.iter().flat_map(|l| [&l.w, &l.b])
    }

    /// Mutable parameter tensors in optimizer order, allocation-free.
    pub fn params_iter_mut(&mut self) -> impl Iterator<Item = &mut Tensor> + '_ {
        self.layers.iter_mut().flat_map(|l| [&mut l.w, &mut l.b])
    }

    pub fn param_count(&self) -> usize {
        self.params_iter().map(|p| p.len()).sum()
    }

    /// Polyak soft update: self = (1-tau)*self + tau*src. Allocation-free
    /// (runs twice per SAC gradient update, inside the zero-alloc gate).
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        for (dst, s) in self.params_iter_mut().zip(src.params_iter()) {
            dst.lerp_into(1.0 - tau, s, tau);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference gradient check through a 2-hidden-layer MLP for
    /// both parameter grads and input grads, with tanh and relu.
    #[test]
    fn gradcheck_mlp() {
        for act in [Activation::Tanh, Activation::Relu] {
            let mut rng = Rng::new(7);
            let mlp = Mlp::new(&[3, 8, 8, 2], act, &mut rng);
            let x = Tensor::randn(&[4, 3], 1.0, &mut rng);
            let cache = mlp.forward_cached(&x);
            // Loss = sum(output^2)/2 -> dout = output
            let dout = cache.output.clone();
            let (dx, grads) = mlp.backward(&cache, &dout);

            let loss = |m: &Mlp, xx: &Tensor| -> f64 {
                let y = m.forward(xx);
                y.data().iter().map(|&v| 0.5 * (v as f64) * (v as f64)).sum()
            };
            let eps = 1e-3f32;

            // Spot-check a handful of parameter coordinates in every layer.
            for (li, layer) in mlp.layers.iter().enumerate() {
                for idx in [0usize, layer.w.len() / 2, layer.w.len() - 1] {
                    let mut mp = mlp.clone();
                    mp.layers[li].w.data_mut()[idx] += eps;
                    let mut mm = mlp.clone();
                    mm.layers[li].w.data_mut()[idx] -= eps;
                    let fd = (loss(&mp, &x) - loss(&mm, &x)) / (2.0 * eps as f64);
                    let an = grads.layers[li].dw.data()[idx] as f64;
                    assert!(
                        (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                        "act {act:?} layer {li} w[{idx}]: fd={fd} an={an}"
                    );
                }
            }
            // Input gradient.
            for idx in 0..x.len() {
                let mut xp = x.clone();
                xp.data_mut()[idx] += eps;
                let mut xm = x.clone();
                xm.data_mut()[idx] -= eps;
                let fd = (loss(&mlp, &xp) - loss(&mlp, &xm)) / (2.0 * eps as f64);
                let an = dx.data()[idx] as f64;
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "act {act:?} dx[{idx}]: fd={fd} an={an}"
                );
            }
        }
    }

    /// True bitwise comparison (derived `PartialEq` would equate `-0.0`
    /// and `+0.0` — the one divergence class the `*_into` kernels' FP
    /// equivalence argument has to exclude).
    fn assert_bits_eq(a: &Tensor, b: &Tensor, what: &str) {
        assert_eq!(a.shape(), b.shape(), "{what}: shape mismatch");
        for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
            assert!(x.to_bits() == y.to_bits(), "{what}[{i}]: {x} vs {y}");
        }
    }

    /// The workspace forward/backward must be bit-identical to the
    /// allocating path (finite inputs), including the dx-only variant.
    #[test]
    fn into_paths_match_allocating_bitwise() {
        let mut rng = Rng::new(9);
        for act in [Activation::Tanh, Activation::Relu] {
            let mlp = Mlp::new(&[5, 12, 8, 3], act, &mut rng);
            let x = Tensor::randn(&[6, 5], 1.0, &mut rng);
            let cache0 = mlp.forward_cached(&x);
            let mut cache = MlpCache::for_batch(&mlp, 6);
            mlp.forward_cached_into(&x, &mut cache);
            assert_bits_eq(&cache0.output, &cache.output, &format!("{act:?} output"));
            for (i, (a, b)) in cache0.inputs.iter().zip(&cache.inputs).enumerate() {
                assert_bits_eq(a, b, &format!("{act:?} inputs[{i}]"));
            }

            let dout = cache.output.clone();
            let (dx0, grads0) = mlp.backward(&cache0, &dout);
            let mut scratch = MlpBackScratch::for_batch(&mlp, 6);
            let mut grads = MlpGrads::zeros_like(&mlp);
            let mut dx = Tensor::zeros(&[6, 5]);
            mlp.backward_into(&cache, &dout, &mut scratch, &mut grads, Some(&mut dx));
            assert_bits_eq(&dx0, &dx, &format!("{act:?} dx"));
            for (i, (g0, g)) in grads0.layers.iter().zip(&grads.layers).enumerate() {
                assert_bits_eq(&g0.dw, &g.dw, &format!("{act:?} dw[{i}]"));
                assert_bits_eq(&g0.db, &g.db, &format!("{act:?} db[{i}]"));
            }

            let mut dx2 = Tensor::zeros(&[6, 5]);
            mlp.backward_input_into(&cache, &dout, &mut scratch, &mut dx2);
            assert_bits_eq(&dx0, &dx2, &format!("{act:?} dx-only"));
        }
    }

    #[test]
    fn params_iter_matches_vec_order() {
        let mut rng = Rng::new(13);
        let mut mlp = Mlp::new(&[2, 3, 1], Activation::Relu, &mut rng);
        let from_vec: Vec<Vec<usize>> = mlp.params().iter().map(|t| t.shape().to_vec()).collect();
        let from_iter: Vec<Vec<usize>> =
            mlp.params_iter().map(|t| t.shape().to_vec()).collect();
        assert_eq!(from_vec, from_iter);
        let n_mut = mlp.params_iter_mut().count();
        assert_eq!(n_mut, from_vec.len());
    }

    #[test]
    fn soft_update_moves_toward_source() {
        let mut rng = Rng::new(3);
        let src = Mlp::new(&[2, 4, 1], Activation::Relu, &mut rng);
        let mut dst = Mlp::new(&[2, 4, 1], Activation::Relu, &mut rng);
        let before = dst.layers[0].w.at(0, 0);
        let target = src.layers[0].w.at(0, 0);
        dst.soft_update_from(&src, 0.5);
        let after = dst.layers[0].w.at(0, 0);
        assert!((after - (0.5 * before + 0.5 * target)).abs() < 1e-6);
    }

    #[test]
    fn grad_clip() {
        let mut rng = Rng::new(5);
        let mlp = Mlp::new(&[2, 4, 1], Activation::Tanh, &mut rng);
        let x = Tensor::randn(&[8, 2], 5.0, &mut rng);
        let cache = mlp.forward_cached(&x);
        let dout = Tensor::full(&[8, 1], 100.0);
        let (_, mut grads) = mlp.backward(&cache, &dout);
        grads.clip(1.0);
        assert!(grads.norm() <= 1.0 + 1e-4);
    }
}
