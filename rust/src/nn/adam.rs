//! Adam optimizer (Kingma & Ba, 2015) with bias correction.

use crate::tensor::Tensor;

/// Adam state for a fixed list of parameter tensors.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
}

impl Adam {
    pub fn new(shapes: &[&[usize]], lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            t: 0,
        }
    }

    /// Convenience: build from current parameter tensors.
    pub fn for_params(params: &[&Tensor], lr: f32) -> Adam {
        let shapes: Vec<&[usize]> = params.iter().map(|p| p.shape()).collect();
        Adam::new(&shapes, lr)
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// The full optimizer state (first/second moments and step count), for
    /// checkpointing. Restore with [`Adam::restore_state`].
    pub fn state(&self) -> (&[Tensor], &[Tensor], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Overwrite the optimizer state with a previously captured one. The
    /// moment tensors must match the shapes this optimizer was built for.
    pub fn restore_state(&mut self, m: Vec<Tensor>, v: Vec<Tensor>, t: u64) {
        assert_eq!(m.len(), self.m.len(), "adam moment count changed");
        assert_eq!(v.len(), self.v.len(), "adam moment count changed");
        for (new, old) in m.iter().zip(&self.m).chain(v.iter().zip(&self.v)) {
            assert_eq!(new.shape(), old.shape(), "adam moment shape changed");
        }
        self.m = m;
        self.v = v;
        self.t = t;
    }

    /// Apply one update. `params` and `grads` must be in the same, fixed
    /// order used at construction.
    pub fn step(&mut self, params: Vec<&mut Tensor>, grads: &[&Tensor]) {
        assert_eq!(params.len(), self.m.len(), "param count changed");
        assert_eq!(grads.len(), self.m.len(), "grad count mismatch");
        self.step_pairs(params.into_iter().zip(grads.iter().copied()));
    }

    /// Fused, allocation-free update: consume `(param, grad)` pairs in the
    /// fixed construction order, walking the moment vectors in one pass
    /// instead of materializing `Vec<&mut Tensor>` / `Vec<&Tensor>` per
    /// step. Bit-identical to [`Adam::step`] (same per-element math); the
    /// SAC hot loop drives it with
    /// `opt.step_pairs(net.params_iter_mut().zip(grads.iter()))`.
    pub fn step_pairs<'p, 'g, I>(&mut self, pairs: I)
    where
        I: Iterator<Item = (&'p mut Tensor, &'g Tensor)>,
    {
        self.t += 1;
        let (beta1, beta2, lr, eps) = (self.beta1, self.beta2, self.lr, self.eps);
        let b1t = 1.0 - beta1.powi(self.t as i32);
        let b2t = 1.0 - beta2.powi(self.t as i32);
        let mut pairs = pairs;
        for (m, v) in self.m.iter_mut().zip(self.v.iter_mut()) {
            let (p, g) = pairs.next().expect("adam: fewer params than moments");
            assert_eq!(p.shape(), g.shape(), "adam shape mismatch");
            let (pd, gd) = (p.data_mut(), g.data());
            let (md, vd) = (m.data_mut(), v.data_mut());
            for i in 0..pd.len() {
                md[i] = beta1 * md[i] + (1.0 - beta1) * gd[i];
                vd[i] = beta2 * vd[i] + (1.0 - beta2) * gd[i] * gd[i];
                let mhat = md[i] / b1t;
                let vhat = vd[i] / b2t;
                pd[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
        assert!(pairs.next().is_none(), "adam: more params than moments");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam must minimize a quadratic f(x) = 0.5*||x - c||^2 quickly.
    #[test]
    fn minimizes_quadratic() {
        let c = [3.0f32, -1.5, 0.25];
        let mut x = Tensor::from_vec(&[3], vec![0.0; 3]);
        let mut opt = Adam::for_params(&[&x], 0.05);
        for _ in 0..2000 {
            let g =
                Tensor::from_vec(&[3], x.data().iter().zip(&c).map(|(xi, ci)| xi - ci).collect());
            opt.step(vec![&mut x], &[&g]);
        }
        for (xi, ci) in x.data().iter().zip(&c) {
            assert!((xi - ci).abs() < 1e-2, "{xi} vs {ci}");
        }
    }

    /// First step magnitude equals lr regardless of gradient scale
    /// (bias-corrected Adam property).
    #[test]
    fn first_step_is_lr_sized() {
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut x = Tensor::from_vec(&[1], vec![0.0]);
            let mut opt = Adam::for_params(&[&x], 0.1);
            let g = Tensor::from_vec(&[1], vec![scale]);
            opt.step(vec![&mut x], &[&g]);
            assert!(
                (x.data()[0] + 0.1).abs() < 1e-3,
                "scale {scale}: step {}",
                x.data()[0]
            );
        }
    }

    /// The fused pair-iterator step and the Vec-based step must produce
    /// bit-identical trajectories.
    #[test]
    fn step_pairs_matches_step_bitwise() {
        let mut x1 = Tensor::from_vec(&[3], vec![0.5, -1.0, 2.0]);
        let mut x2 = x1.clone();
        let mut o1 = Adam::for_params(&[&x1], 0.03);
        let mut o2 = Adam::for_params(&[&x2], 0.03);
        let g = Tensor::from_vec(&[3], vec![0.1, -0.2, 0.33]);
        for _ in 0..7 {
            o1.step(vec![&mut x1], &[&g]);
            o2.step_pairs([(&mut x2, &g)].into_iter());
        }
        for (a, b) in x1.data().iter().zip(x2.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "fewer params")]
    fn step_pairs_rejects_short_iterator() {
        let x = Tensor::zeros(&[2]);
        let mut opt = Adam::for_params(&[&x], 0.1);
        opt.step_pairs(std::iter::empty::<(&mut Tensor, &Tensor)>());
    }

    #[test]
    fn state_roundtrip_continues_identically() {
        let mut x1 = Tensor::from_vec(&[2], vec![1.0, -2.0]);
        let mut opt1 = Adam::for_params(&[&x1], 0.05);
        let g = Tensor::from_vec(&[2], vec![0.3, -0.7]);
        for _ in 0..5 {
            opt1.step(vec![&mut x1], &[&g]);
        }
        // Capture, rebuild a fresh optimizer, restore, and continue.
        let (m, v, t) = opt1.state();
        let (m, v) = (m.to_vec(), v.to_vec());
        let mut x2 = x1.clone();
        let mut opt2 = Adam::for_params(&[&x2], 0.05);
        opt2.restore_state(m, v, t);
        for _ in 0..5 {
            opt1.step(vec![&mut x1], &[&g]);
            opt2.step(vec![&mut x2], &[&g]);
        }
        for (a, b) in x1.data().iter().zip(x2.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let mut x = Tensor::zeros(&[2]);
        let mut opt = Adam::for_params(&[&x], 0.1);
        let g = Tensor::zeros(&[3]);
        opt.step(vec![&mut x], &[&g]);
    }
}
