//! Adam optimizer (Kingma & Ba, 2015) with bias correction.

use crate::tensor::Tensor;

/// Adam state for a fixed list of parameter tensors.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
}

impl Adam {
    pub fn new(shapes: &[&[usize]], lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            t: 0,
        }
    }

    /// Convenience: build from current parameter tensors.
    pub fn for_params(params: &[&Tensor], lr: f32) -> Adam {
        let shapes: Vec<&[usize]> = params.iter().map(|p| p.shape()).collect();
        Adam::new(&shapes, lr)
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Apply one update. `params` and `grads` must be in the same, fixed
    /// order used at construction.
    pub fn step(&mut self, params: Vec<&mut Tensor>, grads: &[&Tensor]) {
        assert_eq!(params.len(), self.m.len(), "param count changed");
        assert_eq!(grads.len(), self.m.len(), "grad count mismatch");
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for ((p, g), (m, v)) in params
            .into_iter()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.shape(), g.shape(), "adam shape mismatch");
            let (pd, gd) = (p.data_mut(), g.data());
            let (md, vd) = (m.data_mut(), v.data_mut());
            for i in 0..pd.len() {
                md[i] = self.beta1 * md[i] + (1.0 - self.beta1) * gd[i];
                vd[i] = self.beta2 * vd[i] + (1.0 - self.beta2) * gd[i] * gd[i];
                let mhat = md[i] / b1t;
                let vhat = vd[i] / b2t;
                pd[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam must minimize a quadratic f(x) = 0.5*||x - c||^2 quickly.
    #[test]
    fn minimizes_quadratic() {
        let c = [3.0f32, -1.5, 0.25];
        let mut x = Tensor::from_vec(&[3], vec![0.0; 3]);
        let mut opt = Adam::for_params(&[&x], 0.05);
        for _ in 0..2000 {
            let g =
                Tensor::from_vec(&[3], x.data().iter().zip(&c).map(|(xi, ci)| xi - ci).collect());
            opt.step(vec![&mut x], &[&g]);
        }
        for (xi, ci) in x.data().iter().zip(&c) {
            assert!((xi - ci).abs() < 1e-2, "{xi} vs {ci}");
        }
    }

    /// First step magnitude equals lr regardless of gradient scale
    /// (bias-corrected Adam property).
    #[test]
    fn first_step_is_lr_sized() {
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut x = Tensor::from_vec(&[1], vec![0.0]);
            let mut opt = Adam::for_params(&[&x], 0.1);
            let g = Tensor::from_vec(&[1], vec![scale]);
            opt.step(vec![&mut x], &[&g]);
            assert!(
                (x.data()[0] + 0.1).abs() < 1e-3,
                "scale {scale}: step {}",
                x.data()[0]
            );
        }
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let mut x = Tensor::zeros(&[2]);
        let mut opt = Adam::for_params(&[&x], 0.1);
        let g = Tensor::zeros(&[3]);
        opt.step(vec![&mut x], &[&g]);
    }
}
