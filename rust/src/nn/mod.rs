//! Minimal neural-network stack with hand-written backpropagation.
//!
//! Powers the SAC agent (L3). Only what SAC needs: dense layers, ReLU /
//! tanh activations, an MLP container that caches forward activations for
//! the backward pass, and Adam. Gradients are verified against finite
//! differences in the tests below — that check is the foundation the RL
//! correctness rests on.
//!
//! Every forward/backward entry point has a workspace (`*_into`) twin
//! writing into caller-owned buffers ([`MlpCache`], [`MlpBackScratch`],
//! [`MlpGrads`]) so the SAC training loop runs allocation-free in the
//! steady state; the twins are bit-identical to the allocating paths for
//! finite inputs (pinned by `rust/tests/prop_train.rs`).

#![deny(clippy::redundant_clone)]

pub mod adam;
pub mod linear;
pub mod mlp;

pub use adam::Adam;
pub use linear::Linear;
pub use mlp::{Mlp, MlpBackScratch, MlpCache, MlpGrads};

/// Hidden-layer activation functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Tanh,
}

impl Activation {
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Tanh => x.tanh(),
        }
    }

    /// Derivative expressed in terms of the *post*-activation value `y`,
    /// which is what the cache stores.
    #[inline]
    pub fn deriv_from_output(self, y: f32) -> f32 {
        match self {
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Tanh => 1.0 - y * y,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activation_derivatives() {
        // tanh'(x) = 1 - tanh(x)^2, checked at x=0.7.
        let y = Activation::Tanh.apply(0.7);
        let d = Activation::Tanh.deriv_from_output(y);
        // f32 finite differences at eps=1e-3 carry ~1e-3 noise.
        let fd = (Activation::Tanh.apply(0.7 + 1e-3) - Activation::Tanh.apply(0.7 - 1e-3)) / 2e-3;
        assert!((d - fd).abs() < 1e-2, "{d} vs {fd}");

        assert_eq!(Activation::Relu.apply(-1.0), 0.0);
        assert_eq!(Activation::Relu.deriv_from_output(0.0), 0.0);
        assert_eq!(Activation::Relu.deriv_from_output(2.0), 1.0);
    }
}
