//! # EDCompress
//!
//! A production-grade reproduction of *"EDCompress: Energy-Aware Model
//! Compression with Dataflow"* (Wang, Luo, Zhou, Goh, 2020) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The library couples a **dataflow-aware accelerator cost model** (energy
//! and area of a spatial PE array under any of the 15 loop-pair dataflows)
//! with **multi-step model compression** (per-layer quantization depth and
//! pruning remaining-amount, Eq. 1 of the paper) searched by a **soft
//! actor-critic agent** implemented from scratch in Rust (Eq. 2–4).
//!
//! Layer map (see `DESIGN.md`):
//! - **L3 (this crate)** — coordinator, SAC agent, cost model, datasets,
//!   baselines, report generation. Owns the whole run-time loop.
//! - **L2 (python/compile)** — JAX train/infer graphs per network, lowered
//!   once to HLO text in `artifacts/` and executed from Rust via PJRT.
//! - **L1 (python/compile/kernels)** — Pallas fake-quant matmul/conv
//!   kernels (interpret mode) inside the L2 graphs.
pub mod baselines;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dataflow;
pub mod energy;
pub mod envs;
pub mod model;
pub mod nn;
pub mod report;
pub mod rl;
pub mod runtime;
pub mod snapshot;
pub mod tensor;
pub mod train;
pub mod util;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::compress::{self, CompressionState};
    pub use crate::coordinator::{self, Coordinator, SearchOutcome};
    pub use crate::dataflow::{Dataflow, LoopDim};
    pub use crate::energy::{self, CostReport, EnergyConfig};
    pub use crate::envs::{AccuracyOracle, CompressionEnv, EnvConfig, SurrogateOracle};
    pub use crate::model::{self, LayerKind, LayerSpec, Network};
    pub use crate::rl::sac::{SacAgent, SacConfig};
    pub use crate::util::rng::Rng;
}
