//! Minimal, API-compatible subset of the `loom` model-checking crate.
//!
//! The build environment has no crates-io access, so this vendored crate
//! provides exactly the surface `edcompress` compiles against under
//! `--cfg loom`: [`model`], `thread::spawn`/`yield_now`, and
//! `sync::{Mutex, Condvar, Arc, atomic}`.
//!
//! **Honesty note — this is not a DPOR model checker.** Upstream loom
//! exhaustively enumerates thread interleavings; this stand-in is a
//! *bounded randomized-schedule explorer*: [`model`] reruns the closure
//! for a fixed number of deterministically-seeded iterations, and every
//! lock/wait/notify/spawn passes through a schedule-perturbation point
//! ([`sched::interleave`]) that injects yields and micro-sleeps driven by
//! a shared xorshift state. That widens the set of interleavings the OS
//! scheduler produces far beyond a plain stress test while keeping runs
//! reproducible in aggregate, but it cannot prove absence of races.
//!
//! The API is kept signature-compatible with upstream loom for the
//! operations used here, so swapping in the real crate is a one-line
//! `Cargo.toml` change once a registry is reachable — the models in
//! `rust/tests/loom_models.rs` are written against loom's documented
//! semantics, not this file's.
//!
//! Iteration count defaults to 64 and can be overridden with the
//! `EDC_LOOM_ITERS` environment variable (upstream loom has an analogous
//! `LOOM_MAX_BRANCHES`-family of tuning knobs).

/// Deterministically-seeded schedule perturbation.
pub mod sched {
    use std::sync::atomic::{AtomicU64, Ordering};

    static STATE: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);

    /// Number of schedule-exploration iterations [`crate::model`] runs.
    pub fn iterations() -> usize {
        std::env::var("EDC_LOOM_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64)
    }

    pub(crate) fn reseed(seed: u64) {
        STATE.store(seed | 1, Ordering::SeqCst);
    }

    fn next() -> u64 {
        // xorshift64 over one shared atomic. Cross-thread races on the
        // RNG state itself only add schedule diversity — determinism of
        // the *model under test* is what the assertions check, not
        // determinism of the exploration order.
        let mut x = STATE.load(Ordering::Relaxed);
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        STATE.store(x, Ordering::Relaxed);
        x
    }

    /// Perturbation point: called before and after every instrumented
    /// synchronization operation.
    pub fn interleave() {
        let r = next();
        if r % 4 == 0 {
            std::thread::yield_now();
        }
        if r % 64 == 0 {
            std::thread::sleep(std::time::Duration::from_micros(r % 97));
        }
    }
}

/// Run `f` under bounded randomized-schedule exploration.
///
/// Upstream loom enumerates interleavings exhaustively; here `f` is rerun
/// [`sched::iterations`] times, each with a distinct deterministic seed
/// feeding the perturbation points inside `loom::sync`/`loom::thread`.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    for i in 0..sched::iterations() as u64 {
        sched::reseed(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i.wrapping_add(1)));
        f();
    }
}

/// Instrumented `std::thread` subset.
pub mod thread {
    pub use std::thread::{
        available_parallelism, current, panicking, park, sleep, yield_now, JoinHandle, Result,
        Thread,
    };

    /// `std::thread::spawn` with perturbation points around the handoff.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        crate::sched::interleave();
        std::thread::spawn(move || {
            crate::sched::interleave();
            f()
        })
    }
}

/// Instrumented `std::sync` subset.
pub mod sync {
    pub use std::sync::{Arc, LockResult, MutexGuard, PoisonError, TryLockResult};

    pub mod atomic {
        pub use std::sync::atomic::*;
    }

    /// `std::sync::Mutex` with schedule perturbation on every acquire.
    ///
    /// Returns std's own `LockResult`/`MutexGuard` so poisoning semantics
    /// (and recovery via `PoisonError::into_inner`) are exactly std's.
    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T> Mutex<T> {
        pub fn new(value: T) -> Mutex<T> {
            Mutex(std::sync::Mutex::new(value))
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            crate::sched::interleave();
            let guard = self.0.lock();
            // Perturb while holding the guard too: stretched critical
            // sections expose waiters that peeked at stale state.
            crate::sched::interleave();
            guard
        }

        pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
            crate::sched::interleave();
            self.0.try_lock()
        }

        pub fn is_poisoned(&self) -> bool {
            self.0.is_poisoned()
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.0.into_inner()
        }

        pub fn get_mut(&mut self) -> LockResult<&mut T> {
            self.0.get_mut()
        }
    }

    /// `std::sync::Condvar` with perturbation on wait/notify edges.
    #[derive(Default)]
    pub struct Condvar(std::sync::Condvar);

    impl Condvar {
        pub fn new() -> Condvar {
            Condvar(std::sync::Condvar::new())
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            crate::sched::interleave();
            self.0.wait(guard)
        }

        pub fn notify_one(&self) {
            crate::sched::interleave();
            self.0.notify_one();
        }

        pub fn notify_all(&self) {
            crate::sched::interleave();
            self.0.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn model_reruns_and_mutex_roundtrips() {
        std::env::set_var("EDC_LOOM_ITERS", "8");
        let runs = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let r = std::sync::Arc::clone(&runs);
        crate::model(move || {
            let m = crate::sync::Mutex::new(1);
            *m.lock().unwrap() += 1;
            assert_eq!(m.into_inner().unwrap(), 2);
            r.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(runs.load(std::sync::atomic::Ordering::SeqCst), 8);
        std::env::remove_var("EDC_LOOM_ITERS");
    }
}
