//! Minimal, API-compatible subset of the `log` crate facade.
//!
//! The build environment has no crates-io access, so this vendored crate
//! provides exactly the surface the workspace uses: the five level
//! macros, [`Log`], [`Level`]/[`LevelFilter`], [`Record`]/[`Metadata`],
//! [`set_boxed_logger`] and [`set_max_level`]. Semantics follow the real
//! facade: nothing is emitted until a logger is installed, and records
//! above the max level are filtered before reaching the logger.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single log record. Ordered `Error < Warn < ... < Trace`
/// (a smaller level is more severe), matching the real crate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(s)
    }
}

/// Global verbosity ceiling; `Off` disables everything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata of a record: its level and the module that produced it.
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    level: Level,
    target: &'a str,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn metadata(&self) -> Metadata<'a> {
        Metadata {
            level: self.level,
            target: self.target,
        }
    }
}

/// A log sink. Implementations must be thread-safe: records can arrive
/// from any thread (e.g. sweep workers).
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

/// Install the global logger. Fails (without replacing) if one exists.
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current verbosity ceiling as a raw ordinal (macro plumbing).
pub fn max_level_ordinal() -> usize {
    MAX_LEVEL.load(Ordering::Relaxed)
}

/// Macro plumbing: filter, then dispatch to the installed logger.
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if (level as usize) > max_level_ordinal() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            level,
            target,
            args,
        };
        if logger.enabled(&record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_facade() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
        assert!((LevelFilter::Off as usize) < (LevelFilter::Error as usize));
    }

    #[test]
    fn logging_without_logger_is_a_noop() {
        // Must not panic even though no logger is installed in this
        // test binary.
        crate::info!("no logger installed: {}", 42);
    }
}
