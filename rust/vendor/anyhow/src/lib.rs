//! Minimal, API-compatible subset of the `anyhow` error crate.
//!
//! The build environment has no crates-io access, so this vendored crate
//! covers the surface the workspace uses: [`Error`], [`Result`], the
//! `anyhow!` / `bail!` / `ensure!` macros, and the [`Context`] extension
//! trait for `Result` and `Option`. Like the real crate:
//!
//! - `{}` (Display) prints only the outermost message/context;
//! - `{:#}` (alternate Display) prints the whole chain joined by `": "`;
//! - `{:?}` (Debug) prints the message plus a `Caused by:` list;
//! - any `std::error::Error + Send + Sync + 'static` converts via `?`
//!   (its `source()` chain is captured as strings at conversion time);
//! - `Error` itself does **not** implement `std::error::Error`, which is
//!   what makes the blanket `From` impl coherent.

use std::fmt::{self, Debug, Display};

/// A dynamic error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message (the `anyhow!` entry point).
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Internal conversion trait mirroring real `anyhow`'s `ext::StdError`:
/// implemented for [`Error`] itself and blanket-implemented for every
/// `std::error::Error`, so a single `Context` impl covers both
/// `Result<_, anyhow::Error>` and `Result<_, E: std::error::Error>`.
/// (Coherent because `Error` does not implement `std::error::Error`.)
mod ext {
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| "nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");

        let ar: Result<()> = Err(anyhow!("inner {}", 7));
        let e = ar.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(5).is_err());
        assert!(format!("{}", f(99).unwrap_err()).contains("99"));
    }
}
