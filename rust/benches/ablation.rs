//! Ablation benches: the paper's prose hyper-parameter claims (gamma=0.9,
//! lambda=3 optimal) regenerated as tables.
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::ablation;

fn main() {
    banner("Ablations: lambda (Eq.4) and gamma (Eq.1)");
    let eps = bench_episodes();
    let mut t = BenchTimer::new("ablation sweeps (8 searches)");
    let mut out = (String::new(), String::new());
    t.run(1, || {
        out = (
            ablation::lambda_sweep(eps, 0).render(),
            ablation::gamma_sweep(eps, 0).render(),
        )
    });
    println!("{}\n{}", out.0, out.1);
    t.report();
}
