//! Regenerates Figure 5 (optimization curves, 3 networks x 4 dataflows).
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::figures;

fn main() {
    banner("Figure 5: optimization process (energy curves + accuracy)");
    let eps = bench_episodes();
    let mut t = BenchTimer::new("fig5 (3 networks x 4 dataflows)");
    let mut out = (Vec::new(), Vec::new());
    t.run(1, || out = figures::fig5(eps, 0));
    for table in &out.0 {
        println!("{}", table.render());
    }
    println!("CSV series: {:?}", out.1);
    t.report();
}
