//! Regenerates Table 4 (per-layer LeNet-5 energy/area, 6 baselines).
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::tables;

fn main() {
    banner("Table 4: per-layer energy (uJ) / area (mm^2) on LeNet-5");
    let eps = bench_episodes();
    let mut t = BenchTimer::new(&format!("table4 search ({eps} episodes x 4 dataflows)"));
    let mut rendered = Vec::new();
    t.run(1, || {
        let (tables4, _outs) = tables::table4(eps, 0);
        rendered = tables4.iter().map(|t| t.render()).collect();
    });
    for r in &rendered {
        println!("{r}");
    }
    t.report();
}
