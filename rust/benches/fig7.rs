//! Regenerates Figure 7 (quant-only vs prune-only vs both).
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::figures;

fn main() {
    banner("Figure 7: technique ablation (quant-only / prune-only / both)");
    let eps = bench_episodes();
    let mut t = BenchTimer::new("fig7 (3 modes x 3 networks x 4 dataflows)");
    let mut rendered = String::new();
    t.run(1, || rendered = figures::fig7(eps, 0).render());
    println!("{rendered}");
    t.report();
}
