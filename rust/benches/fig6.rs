//! Regenerates Figure 6 (PE vs data-movement breakdown, before/after).
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::figures;

fn main() {
    banner("Figure 6: energy breakdown before/after EDCompress");
    let eps = bench_episodes();
    let mut t = BenchTimer::new("fig6");
    let mut rendered = String::new();
    t.run(1, || rendered = figures::fig6(eps, 0).render());
    println!("{rendered}");
    t.report();
}
