//! Regenerates Table 3 (EDCompress vs [22][29], VGG-16/CIFAR-10).
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::tables;

fn main() {
    banner("Table 3: EDCompress vs filter-pruning baselines (VGG-16/CIFAR)");
    let eps = bench_episodes();
    let mut t = BenchTimer::new(&format!("table3 search ({eps} episodes x 4 dataflows)"));
    let mut rendered = String::new();
    t.run(1, || {
        let (table, _outs) = tables::table3(eps, 0);
        rendered = table.render();
    });
    println!("{rendered}");
    t.report();
}
