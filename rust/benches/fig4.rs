//! Regenerates Figure 4 (layer-wise DC vs EDC breakdown + params line).
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::figures;

fn main() {
    banner("Figure 4: layer-wise energy/area, DC vs EDC (LeNet-5)");
    let eps = bench_episodes();
    let mut t = BenchTimer::new("fig4");
    let mut out = (Vec::new(), String::new());
    t.run(1, || out = figures::fig4(eps, 0));
    for table in &out.0 {
        println!("{}", table.render());
    }
    println!("CSV: {}", out.1);
    t.report();
}
