//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf):
//! cost-model evaluation (full, batched, incremental), SAC update step,
//! GEMM kernel, env step, and — when artifacts exist — the PJRT execute
//! round-trip.
//!
//! The incremental-engine sections print explicit speedup factors:
//! `evaluate_incremental` + `CostCache` versus full re-evaluation over a
//! recorded 32-step `CompressionEnv` episode, and `evaluate_batch` versus
//! 15 individual `evaluate` calls for `rank_dataflows`.
#[path = "common.rs"]
mod common;
use common::{banner, BenchTimer};
use edcompress::compress::CompressionState;
use edcompress::dataflow::Dataflow;
use edcompress::energy::{self, cache, EnergyConfig};
use edcompress::envs::{CompressionEnv, EnvConfig, SurrogateOracle};
use edcompress::model::{zoo, Network};
use edcompress::rl::sac::{SacAgent, SacConfig};
use edcompress::rl::Env;
use edcompress::tensor::Tensor;
use edcompress::util::rng::Rng;

/// Record the state trajectory of one 32-step episode (policy-free, a
/// fixed gentle compression action) so both evaluation paths see the
/// exact same work.
fn episode_trajectory(net: &Network, steps: usize) -> Vec<CompressionState> {
    let limits = edcompress::compress::CompressionLimits::default();
    let l = net.num_compute_layers();
    let mut state = CompressionState::uniform(net, 8.0, 1.0);
    let mut rng = Rng::new(7);
    let mut traj = Vec::with_capacity(steps);
    for t in 0..steps {
        let action: Vec<f64> = (0..2 * l).map(|_| rng.range(-0.4, 0.1)).collect();
        state.apply_action(&action, t, &limits);
        traj.push(state.clone());
    }
    traj
}

fn bench_incremental_vs_full(net: &Network, df: Dataflow, cfg: &EnergyConfig, min_speedup: f64) {
    let steps = 32;
    let traj = episode_trajectory(net, steps);

    let mut t_full = BenchTimer::new(&format!("episode eval FULL {} {}", net.name, df.label()));
    t_full.run(60, || {
        let mut acc = 0.0;
        for s in &traj {
            acc += energy::evaluate(net, s, df, cfg).total_energy();
        }
        acc
    });
    t_full.report();

    // The incremental evaluator persists across episodes exactly like the
    // one inside CompressionEnv, so steady-state search iterations mostly
    // hit the layer cache.
    let mut ev = cache::IncrementalEvaluator::new(net, df, cfg);
    let mut t_inc = BenchTimer::new(&format!("episode eval INC {} {}", net.name, df.label()));
    t_inc.run(60, || {
        let mut acc = 0.0;
        for s in &traj {
            acc += ev.evaluate(net, s, cfg).0;
        }
        acc
    });
    t_inc.report();

    let speedup = t_full.mean_ns() / t_inc.mean_ns().max(1.0);
    println!(
        "  -> incremental speedup {:.1}x over full re-evaluation ({} steps, cache: {} hits / {} misses)",
        speedup,
        steps,
        ev.cache().hits(),
        ev.cache().misses()
    );
    // Acceptance gate: >= 5x on the steady-state episode for the
    // deep-network case (vgg16_cifar, where per-layer work dominates);
    // LeNet-5's 4 compute layers leave fixed per-step overhead on top,
    // so it carries a 3x floor rather than the headline gate.
    assert!(
        speedup >= min_speedup,
        "incremental evaluation speedup {speedup:.1}x below the {min_speedup}x target for {}",
        net.name
    );
}

fn bench_batch_vs_individual(net: &Network, cfg: &EnergyConfig) {
    let s = CompressionState::uniform(net, 6.0, 0.6);
    let dfs = Dataflow::all_fifteen();

    let mut t_ind = BenchTimer::new(&format!("rank 15 dataflows INDIVIDUAL {}", net.name));
    t_ind.run(50, || {
        let mut acc = 0.0;
        for &df in &dfs {
            acc += energy::evaluate(net, &s, df, cfg).total_energy();
        }
        acc
    });
    t_ind.report();

    let mut cost_cache = cache::CostCache::new(net, cfg);
    let mut t_batch = BenchTimer::new(&format!("rank 15 dataflows BATCH+cache {}", net.name));
    t_batch.run(50, || {
        energy::evaluate_batch(net, &s, &dfs, cfg, &mut cost_cache)
            .iter()
            .map(|r| r.total_energy())
            .sum::<f64>()
    });
    t_batch.report();
    println!(
        "  -> batch speedup {:.1}x over 15 individual evaluates",
        t_ind.mean_ns() / t_batch.mean_ns().max(1.0)
    );
}

fn main() {
    banner("L3 hot paths");
    let cfg = EnergyConfig::default();

    // 1. Cost-model evaluation (called on every RL step in sweeps).
    for net in [zoo::lenet5(), zoo::vgg16_cifar(), zoo::mobilenet_v1()] {
        let s = CompressionState::uniform(&net, 6.0, 0.6);
        let mut t = BenchTimer::new(&format!("energy::evaluate {}", net.name));
        t.run(200, || energy::evaluate(&net, &s, Dataflow::XY, &cfg).total_energy());
        t.report();
    }

    // 2. Incremental engine vs full re-evaluation (this PR's hot-path
    // claim) on a small and a large network.
    banner("incremental engine");
    bench_incremental_vs_full(&zoo::lenet5(), Dataflow::XY, &cfg, 3.0);
    bench_incremental_vs_full(&zoo::vgg16_cifar(), Dataflow::CICO, &cfg, 5.0);

    // 3. All-15-dataflow ranking: batched+cached vs individual.
    banner("dataflow ranking");
    bench_batch_vs_individual(&zoo::vgg16_cifar(), &cfg);
    {
        let net = zoo::vgg16_cifar();
        let s = CompressionState::uniform(&net, 6.0, 0.6);
        let mut t = BenchTimer::new("rank_dataflows vgg16 (15 dataflows)");
        t.run(50, || {
            edcompress::coordinator::sweep::rank_dataflows(&net, &s, &cfg)
        });
        t.report();
    }

    // 4. GEMM kernel (SAC's inner loop).
    banner("RL substrate");
    {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[64, 166], 1.0, &mut rng);
        let b = Tensor::randn(&[166, 128], 1.0, &mut rng);
        let mut t = BenchTimer::new("tensor::matmul 64x166x128");
        t.run(300, || a.matmul(&b));
        t.report();
    }

    // 5. SAC update step at LeNet env dimensions.
    {
        let net = zoo::lenet5();
        let oracle = SurrogateOracle::new(&net, 0);
        let mut env = CompressionEnv::new(
            net,
            Dataflow::XY,
            Box::new(oracle),
            EnvConfig::default(),
            cfg.clone(),
        );
        let mut agent = SacAgent::new(env.state_dim(), env.action_dim(), SacConfig::default());
        // Fill replay.
        let mut s = env.reset();
        for _ in 0..256 {
            let a = agent.act(&s);
            let (s2, r, d) = env.step(&a);
            agent.observe(&s, &a, r, &s2, d);
            s = if d { env.reset() } else { s2 };
        }
        let mut t = BenchTimer::new("SAC update_once (batch 64, 128x128)");
        t.run(100, || agent.update_once());
        t.report();

        let mut t = BenchTimer::new("CompressionEnv::step (surrogate)");
        let action = vec![-0.2; env.action_dim()];
        env.reset();
        t.run(200, || {
            let (_s, _r, done) = env.step(&action);
            if done {
                env.reset();
            }
        });
        t.report();
    }

    // 6. PJRT execute round-trip (skipped without artifacts).
    if edcompress::runtime::artifacts_available("lenet5") {
        use edcompress::runtime::{literal, Runtime};
        let rt = Runtime::cpu().expect("pjrt");
        let art = rt
            .load_artifact(&edcompress::runtime::artifacts_dir().join("kernel_fq.hlo.txt"))
            .expect("artifact");
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[32, 128], 1.0, &mut rng);
        let mut t = BenchTimer::new("PJRT kernel_fq execute (32x128)");
        t.run(100, || {
            let inputs = vec![
                literal::tensor_to_literal(&w).unwrap(),
                literal::scalar_literal(7.0),
                literal::scalar_literal(0.1),
            ];
            art.run(&inputs).unwrap()
        });
        t.report();
    } else {
        println!("PJRT bench skipped: artifacts missing (make artifacts)");
    }
}
