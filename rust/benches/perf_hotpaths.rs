//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf):
//! cost-model evaluation, SAC update step, GEMM kernel, env step, and —
//! when artifacts exist — the PJRT execute round-trip.
#[path = "common.rs"]
mod common;
use common::{banner, BenchTimer};
use edcompress::compress::CompressionState;
use edcompress::dataflow::Dataflow;
use edcompress::energy::{self, EnergyConfig};
use edcompress::envs::{CompressionEnv, EnvConfig, SurrogateOracle};
use edcompress::model::zoo;
use edcompress::rl::sac::{SacAgent, SacConfig};
use edcompress::rl::Env;
use edcompress::tensor::Tensor;
use edcompress::util::rng::Rng;

fn main() {
    banner("L3 hot paths");
    let cfg = EnergyConfig::default();

    // 1. Cost-model evaluation (called 4x per RL step in sweeps).
    for net in [zoo::lenet5(), zoo::vgg16_cifar(), zoo::mobilenet_v1()] {
        let s = CompressionState::uniform(&net, 6.0, 0.6);
        let mut t = BenchTimer::new(&format!("energy::evaluate {}", net.name));
        t.run(200, || energy::evaluate(&net, &s, Dataflow::XY, &cfg).total_energy());
        t.report();
    }

    // 2. All-15-dataflow ranking.
    {
        let net = zoo::vgg16_cifar();
        let s = CompressionState::uniform(&net, 6.0, 0.6);
        let mut t = BenchTimer::new("rank_dataflows vgg16 (15 dataflows)");
        t.run(50, || {
            edcompress::coordinator::sweep::rank_dataflows(&net, &s, &cfg)
        });
        t.report();
    }

    // 3. GEMM kernel (SAC's inner loop).
    {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[64, 166], 1.0, &mut rng);
        let b = Tensor::randn(&[166, 128], 1.0, &mut rng);
        let mut t = BenchTimer::new("tensor::matmul 64x166x128");
        t.run(300, || a.matmul(&b));
        t.report();
    }

    // 4. SAC update step at LeNet env dimensions.
    {
        let net = zoo::lenet5();
        let oracle = SurrogateOracle::new(&net, 0);
        let mut env = CompressionEnv::new(
            net,
            Dataflow::XY,
            Box::new(oracle),
            EnvConfig::default(),
            cfg.clone(),
        );
        let mut agent = SacAgent::new(env.state_dim(), env.action_dim(), SacConfig::default());
        // Fill replay.
        let mut s = env.reset();
        for _ in 0..256 {
            let a = agent.act(&s);
            let (s2, r, d) = env.step(&a);
            agent.observe(&s, &a, r, &s2, d);
            s = if d { env.reset() } else { s2 };
        }
        let mut t = BenchTimer::new("SAC update_once (batch 64, 128x128)");
        t.run(100, || agent.update_once());
        t.report();

        let mut t = BenchTimer::new("CompressionEnv::step (surrogate)");
        let action = vec![-0.2; env.action_dim()];
        env.reset();
        t.run(200, || {
            let (_s, _r, done) = env.step(&action);
            if done {
                env.reset();
            }
        });
        t.report();
    }

    // 5. PJRT execute round-trip (skipped without artifacts).
    if edcompress::runtime::artifacts_available("lenet5") {
        use edcompress::runtime::{literal, Runtime};
        let rt = Runtime::cpu().expect("pjrt");
        let art = rt
            .load_artifact(&edcompress::runtime::artifacts_dir().join("kernel_fq.hlo.txt"))
            .expect("artifact");
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[32, 128], 1.0, &mut rng);
        let mut t = BenchTimer::new("PJRT kernel_fq execute (32x128)");
        t.run(100, || {
            let inputs = vec![
                literal::tensor_to_literal(&w).unwrap(),
                literal::scalar_literal(7.0),
                literal::scalar_literal(0.1),
            ];
            art.run(&inputs).unwrap()
        });
        t.report();
    } else {
        println!("PJRT bench skipped: artifacts missing (make artifacts)");
    }
}
