//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf):
//! cost-model evaluation (full, batched, incremental), the fleet-shared
//! cost cache versus private per-seed caches, SAC update step, GEMM
//! kernel, env step, and — when artifacts exist — the PJRT execute
//! round-trip.
//!
//! The incremental-engine sections print explicit speedup factors:
//! `evaluate_incremental` + `CostCache` versus full re-evaluation over a
//! recorded 32-step `CompressionEnv` episode, and `evaluate_batch` versus
//! 15 individual `evaluate` calls for `rank_dataflows`. The fleet section
//! *asserts* that a 4-seed fleet on one `SharedCostCache` reaches a
//! higher steady-state hit-rate than 4 private caches, and the serve
//! section *asserts* that two concurrent same-network jobs on one
//! `edc serve` daemon beat two sequential standalone runs on shared-cache
//! hit-rate (the daemon's registry dedups the cross-job miss set).
//!
//! The train-kernel section *asserts* the PR-5 claims: the workspace
//! (`TrainScratch`) `SacAgent::update_once` must be >= 2x faster than the
//! kept-verbatim PR-4 allocating path (`update_once_reference`) at SAC's
//! real shapes (batch 64, 64x166x128-class GEMMs), while performing
//! **zero** steady-state heap allocations — counted by the thread-local
//! counting allocator below — and producing bit-identical update stats.
//!
//! The snapshot section *asserts* the PR-8 container claim: resuming a
//! 16-seed fleet snapshot from the v4 binary container beats the v3
//! JSON container on resume wall-clock and on peak live heap bytes
//! (tracked by the same counting allocator), with a smaller file.
//!
//! The wire section *asserts* the PR-9 serve claims: a float-heavy
//! submit frame is strictly smaller on the binary wire codec than on
//! newline-JSON while decoding value-identical, and a saturated daemon
//! queue rejects a 50-submit burst with typed `busy` errors in O(1)
//! wall time per rejection without stalling the running job.
//!
//! The router section *asserts* the PR-10 fleet claims: a status
//! round-trip proxied through `edc route` stays within a bounded
//! constant factor of the direct round-trip, and with one of two
//! backends killed and quarantined the router keeps accepting submits
//! at the surviving backend's own rate — the breaker skips the corpse
//! instead of re-dialing it per request.
//!
//! Run with `--test` (e.g. `cargo bench --bench perf_hotpaths -- --test`)
//! for the CI smoke mode: only the asserted gates run (train kernels,
//! fleet cache, serve cache, async throughput, snapshot resume, wire
//! codecs + backpressure, router overhead + failover), in well under a
//! minute.
#[path = "common.rs"]
mod common;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use common::{banner, BenchTimer};
use edcompress::compress::CompressionState;
use edcompress::dataflow::Dataflow;
use edcompress::energy::{self, cache, EnergyConfig};
use edcompress::envs::{CompressionEnv, EnvConfig, SurrogateOracle};
use edcompress::model::{zoo, Network};
use edcompress::rl::sac::{SacAgent, SacConfig};
use edcompress::rl::Env;
use edcompress::tensor::Tensor;
use edcompress::util::rng::Rng;

// ---------------------------------------------------------------------
// Thread-local counting allocator: every `alloc`/`realloc` on the calling
// thread bumps a per-thread counter, so the zero-allocation gate is immune
// to allocator traffic from the daemon/fleet benches' worker threads. It
// also tracks net live bytes and their high-water mark per thread, which
// is what the snapshot-resume gate compares across container formats
// (cross-thread frees can push `live` below a thread's own baseline, so
// both cells are signed). The thread-local slots are const-initialized
// (no lazy allocation), so reading them inside the allocator cannot
// recurse; `try_with` tolerates TLS teardown.
// ---------------------------------------------------------------------

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static TL_LIVE: Cell<i64> = const { Cell::new(0) };
    static TL_PEAK: Cell<i64> = const { Cell::new(0) };
}

struct CountingAlloc;

fn note_alloc_bytes(delta: i64) {
    let _ = TL_LIVE.try_with(|l| {
        let live = l.get() + delta;
        l.set(live);
        let _ = TL_PEAK.try_with(|p| {
            if live > p.get() {
                p.set(live);
            }
        });
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        note_alloc_bytes(layout.size() as i64);
        System.alloc(layout)
    }

    // Forwarded explicitly so `vec![0.0; n]` (Tensor::zeros) keeps its
    // calloc fast path — otherwise the default alloc+memset impl would
    // slow the allocating reference down and flatter the speedup gate.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        note_alloc_bytes(layout.size() as i64);
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        note_alloc_bytes(-(layout.size() as i64));
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        note_alloc_bytes(new_size as i64 - layout.size() as i64);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations performed by this thread so far.
fn thread_allocs() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

/// Run `f` and return its result plus the high-water mark of net-new
/// live heap bytes this thread reached while it ran (the peak working
/// set of a single-threaded operation, as the allocator sees it).
fn with_peak_tracking<T>(f: impl FnOnce() -> T) -> (T, u64) {
    let base = TL_LIVE.with(|l| l.get());
    TL_PEAK.with(|p| p.set(base));
    let out = f();
    let peak = TL_PEAK.with(|p| p.get());
    (out, (peak - base).max(0) as u64)
}

/// Build one replay-filled SAC agent at the LeNet-5 env dimensions —
/// deterministic, so two calls yield bit-identical agents whose scratch
/// and reference update streams stay in lockstep.
fn filled_sac_agent() -> SacAgent {
    let net = zoo::lenet5();
    let oracle = SurrogateOracle::new(&net, 0);
    let mut env = CompressionEnv::new(
        net,
        Dataflow::XY,
        Box::new(oracle),
        EnvConfig::default(),
        EnergyConfig::default(),
    );
    let mut agent = SacAgent::new(env.state_dim(), env.action_dim(), SacConfig::default());
    let mut s = env.reset();
    for _ in 0..256 {
        let a = agent.act(&s);
        let (s2, r, d) = env.step(&a);
        agent.observe(&s, &a, r, &s2, d);
        s = if d { env.reset() } else { s2 };
    }
    agent
}

/// The train-kernel gates (CI bench-smoke): zero steady-state allocations
/// on the workspace `update_once`, >= 2x over the allocating reference,
/// and bit-identical update stats while both paths run in lockstep.
fn bench_train_kernels(iters: usize) {
    let mut fast = filled_sac_agent();
    let mut reference = filled_sac_agent();

    // Lockstep warmup: the first scratch update allocates the workspace;
    // the paired updates must report bit-identical losses throughout.
    for i in 0..3 {
        let uf = fast.update_once();
        let ur = reference.update_once_reference();
        assert_eq!(
            uf.q1_loss.to_bits(),
            ur.q1_loss.to_bits(),
            "scratch vs reference q1 loss diverged at warmup update {i}"
        );
        assert_eq!(
            uf.policy_loss.to_bits(),
            ur.policy_loss.to_bits(),
            "scratch vs reference policy loss diverged at warmup update {i}"
        );
    }

    // Zero-allocation gate: steady-state scratch updates must never touch
    // the allocator (thread-local count, so concurrent benches can't
    // pollute it).
    let before = thread_allocs();
    let mut sink = 0.0;
    for _ in 0..20 {
        sink += fast.update_once().q1_loss;
    }
    let allocs = thread_allocs() - before;

    // Speedup gate: scratch path vs the PR-4 allocating reference.
    let mut t_fast = BenchTimer::new("SAC update_once SCRATCH (batch 64)");
    t_fast.run(iters, || fast.update_once());
    t_fast.report();
    let mut t_ref = BenchTimer::new("SAC update_once REFERENCE (batch 64)");
    t_ref.run(iters, || reference.update_once_reference());
    t_ref.report();
    let speedup = t_ref.mean_ns() / t_fast.mean_ns().max(1.0);
    println!(
        "  -> train-kernel speedup {speedup:.2}x, {allocs} steady-state allocations \
         over 20 updates (loss sink {sink:.4})"
    );
    assert_eq!(
        allocs, 0,
        "steady-state update_once touched the allocator {allocs} times in 20 updates"
    );
    assert!(
        speedup >= 2.0,
        "train-kernel speedup {speedup:.2}x below the 2x gate over the allocating reference"
    );
}

/// Record the state trajectory of one 32-step episode (policy-free, a
/// fixed gentle compression action) so both evaluation paths see the
/// exact same work.
fn episode_trajectory(net: &Network, steps: usize) -> Vec<CompressionState> {
    let limits = edcompress::compress::CompressionLimits::default();
    let l = net.num_compute_layers();
    let mut state = CompressionState::uniform(net, 8.0, 1.0);
    let mut rng = Rng::new(7);
    let mut traj = Vec::with_capacity(steps);
    for t in 0..steps {
        let action: Vec<f64> = (0..2 * l).map(|_| rng.range(-0.4, 0.1)).collect();
        state.apply_action(&action, t, &limits);
        traj.push(state.clone());
    }
    traj
}

/// Per-seed trajectories for the fleet benchmark: each seed follows the
/// shared base episode but deviates on ~25% of its steps, modelling N
/// searches exploring the same region of the compression space (which is
/// exactly when fleet-wide cache sharing pays).
fn fleet_trajectories(net: &Network, steps: usize, seeds: usize) -> Vec<Vec<CompressionState>> {
    let base = episode_trajectory(net, steps);
    (0..seeds)
        .map(|i| {
            let mut rng = Rng::new(100 + i as u64);
            base.iter()
                .map(|s| {
                    let mut s = s.clone();
                    if rng.below(4) == 0 {
                        let slot = rng.below(s.num_layers());
                        s.q[slot] = (s.q[slot] + rng.range(-1.0, 1.0)).clamp(1.0, 8.0);
                        s.p[slot] = (s.p[slot] + rng.range(-0.2, 0.2)).clamp(0.02, 1.0);
                    }
                    s
                })
                .collect()
        })
        .collect()
}

/// The fleet-wide cache claim: N concurrent seeds over one
/// `SharedCostCache` must reach a higher steady-state hit-rate than the
/// same N seeds on private caches, because a miss any seed pays is a hit
/// for every other seed. Asserted, not just printed.
fn bench_fleet_shared_vs_private(
    net: &Network,
    df: Dataflow,
    cfg: &EnergyConfig,
    seeds: usize,
    steps: usize,
) {
    let trajs = fleet_trajectories(net, steps, seeds);
    let passes = 2;

    // Private fleet: one evaluator+cache per seed, all seeds concurrent.
    let t0 = std::time::Instant::now();
    let (mut private_hits, mut private_misses) = (0u64, 0u64);
    std::thread::scope(|scope| {
        let handles: Vec<_> = trajs
            .iter()
            .map(|traj| {
                scope.spawn(move || {
                    let mut ev = cache::IncrementalEvaluator::new(net, df, cfg);
                    for _ in 0..passes {
                        for s in traj {
                            ev.evaluate(net, s, cfg);
                        }
                    }
                    (ev.hits(), ev.misses())
                })
            })
            .collect();
        for h in handles {
            let (hits, misses) = h.join().expect("private fleet worker died");
            private_hits += hits;
            private_misses += misses;
        }
    });
    let t_private = t0.elapsed();

    // Shared fleet: same trajectories, one cache for everyone.
    let shared = cache::SharedCostCache::new(net, cfg);
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for traj in &trajs {
            let shared = &shared;
            scope.spawn(move || {
                let mut ev = cache::IncrementalEvaluator::with_shared(net, df, cfg, shared);
                for _ in 0..passes {
                    for s in traj {
                        ev.evaluate(net, s, cfg);
                    }
                }
            });
        }
    });
    let t_shared = t0.elapsed();

    // Rates are computed from *deterministic* quantities so the CI gate
    // cannot flake on thread scheduling: total lookups (hits+misses —
    // every lookup increments exactly one counter) and, for the shared
    // fleet, the number of distinct cached keys (`len()`). The raw miss
    // counter would also charge racing first-fill double-computes, which
    // depend on how the threads interleave.
    let private_lookups = private_hits + private_misses;
    let private_rate = private_hits as f64 / private_lookups.max(1) as f64;
    let shared_lookups = shared.hits() + shared.misses();
    let shared_cold = shared.len() as u64;
    let shared_rate = 1.0 - shared_cold as f64 / shared_lookups.max(1) as f64;
    println!(
        "  fleet of {seeds} seeds on {} {} ({} steps x {passes} passes): hit-rate \
         shared {:.3} ({} distinct keys, {} raw misses) vs private {:.3} ({} misses), \
         wall {:?} vs {:?}",
        net.name,
        df.label(),
        steps,
        shared_rate,
        shared_cold,
        shared.misses(),
        private_rate,
        private_misses,
        t_shared,
        t_private,
    );
    // Acceptance gate: fleet-wide steady-state hit-rate must beat private
    // caches by a clear margin (cross-seed dedup of the miss set).
    assert!(
        shared_rate >= private_rate + 0.05,
        "shared-cache fleet hit-rate {shared_rate:.3} not clearly above private {private_rate:.3}"
    );
}

/// The serve-path cache claim (CI gate): two concurrent same-network
/// jobs on one `edc serve` daemon reach a higher shared-cache hit-rate
/// than the same two jobs run sequentially as standalone searches, each
/// with its own per-run cache — the daemon's fingerprint-keyed registry
/// dedups the cross-job miss set. Rates are computed from deterministic
/// quantities (total lookups and distinct cached keys — both pure
/// functions of the bit-identical episode streams), so the gate cannot
/// flake on thread scheduling.
fn bench_serve_shared_vs_sequential() {
    use edcompress::coordinator::orchestrator::{Orchestrator, OrchestratorSpec};
    use edcompress::coordinator::service::{Client, ServeConfig, Service};
    use edcompress::util::json::Json;

    fn spec(seed: u64) -> OrchestratorSpec {
        let mut s = OrchestratorSpec::new(zoo::lenet5(), 2, seed);
        s.dataflows = vec![Dataflow::XY];
        s.env.max_steps = 6;
        s.search.episodes = 2;
        s.chunk_episodes = 1;
        s
    }

    // Sequential standalone: each run builds its own fleet cache.
    let t0 = std::time::Instant::now();
    let (mut seq_lookups, mut seq_distinct) = (0u64, 0u64);
    for seed in [11u64, 22] {
        let mut orch = Orchestrator::new(spec(seed));
        orch.run().expect("standalone run failed");
        let cache = orch.shared_cache.as_ref().expect("spec defaults to a shared cache");
        seq_lookups += cache.hits() + cache.misses();
        seq_distinct += cache.len() as u64;
    }
    let t_seq = t0.elapsed();
    let seq_rate = 1.0 - seq_distinct as f64 / seq_lookups.max(1) as f64;

    // Daemon: the same two jobs, concurrently, over one registry cache.
    let dir = std::env::temp_dir().join(format!("edc_bench_serve_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let svc = Service::start(ServeConfig {
        dir: dir.clone(),
        max_concurrent_jobs: 2,
        ..ServeConfig::default()
    })
    .expect("daemon failed to start");
    let mut client = Client::connect(&svc.addr().to_string()).expect("connect");
    let t0 = std::time::Instant::now();
    let ids: Vec<u64> = [11u64, 22]
        .iter()
        .map(|seed| {
            let mut j = Json::obj();
            j.set("net", Json::Str("lenet5".into()))
                .set("seeds", Json::Num(2.0))
                .set("episodes", Json::Num(2.0))
                .set("chunk", Json::Num(1.0))
                .set("steps", Json::Num(6.0))
                .set("seed", Json::Str(seed.to_string()))
                .set("dataflows", Json::Str("X:Y".into()));
            client.submit(&j).expect("submit")
        })
        .collect();
    for id in ids {
        let s = client
            .wait_done(id, std::time::Duration::from_secs(600))
            .expect("wait_done");
        assert_eq!(s.str_or("state", ""), "done", "daemon job failed");
    }
    let t_serve = t0.elapsed();
    let status = client.status(None).expect("status");
    let caches = status.get("caches").and_then(|a| a.as_arr()).expect("cache stats");
    assert_eq!(caches.len(), 1, "both jobs must share one registry cache");
    let hits = caches[0].num_or("hits", 0.0) as u64;
    let misses = caches[0].num_or("misses", 0.0) as u64;
    let distinct = caches[0].num_or("entries", 0.0) as u64;
    let lookups = (hits + misses).max(1);
    let serve_rate = 1.0 - distinct as f64 / lookups as f64;
    client.shutdown().expect("shutdown");
    svc.wait().expect("daemon drain");
    std::fs::remove_dir_all(&dir).ok();

    println!(
        "  serve path: 2 concurrent daemon jobs hit-rate {serve_rate:.3} ({distinct} distinct \
         keys / {lookups} lookups, wall {t_serve:?}) vs 2 sequential standalone runs \
         {seq_rate:.3} ({seq_distinct} distinct / {seq_lookups} lookups, wall {t_seq:?})"
    );
    // The runs are bit-identical either way, so total lookups must match;
    // the daemon's distinct-key union can only be smaller than the
    // standalone sum, strictly so because both jobs visit the shared
    // start state. Asserted, not just printed.
    assert_eq!(
        seq_lookups, lookups,
        "daemon jobs must do exactly the standalone evaluation work"
    );
    assert!(
        serve_rate > seq_rate,
        "serve-path shared-cache hit-rate {serve_rate:.3} not above the sequential \
         standalone rate {seq_rate:.3}"
    );
}

/// The PR-9 wire claims (CI gate), in two halves.
///
/// **Codec payload bytes:** a submit/result-style message whose bulk is
/// a ~1024-point `Json::F64s` curve must be strictly smaller on the
/// binary wire (EDCW + u32 length + v4-container payload, 8 bytes per
/// float) than on the newline-JSON wire (~18 decimal chars per float) —
/// and both frames must decode back value-identical, pinned via the
/// canonical `Display` rendering.
///
/// **Saturated-queue rejection:** with the daemon's one runner busy and
/// its queue full, a burst of overflow submits must each come back as a
/// typed `code:"busy"` rejection, the whole burst in O(1)-per-reject
/// wall time, without stalling the running job — admission control has
/// to be cheapest exactly when the daemon is busiest.
fn bench_wire_codecs_and_backpressure() {
    use edcompress::coordinator::service::wire::{self, WireKind};
    use edcompress::coordinator::service::{Client, ServeConfig, Service};
    use edcompress::util::json::Json;

    // -------- codec payload bytes --------
    let mut rng = Rng::new(7);
    let curve: Vec<f64> = (0..1024).map(|_| rng.range(-4.0, 4.0)).collect();
    let mut msg = Json::obj();
    msg.set("cmd", Json::Str("submit".into()))
        .set("net", Json::Str("vgg16_cifar".into()))
        .set("kind", Json::Str("search".into()))
        .set("priority", Json::Str("high".into()))
        .set("warm_curve", Json::from_f64s(&curve));

    let json_codec = wire::codec_for(WireKind::Json).expect("json codec");
    let json_frame = json_codec.encode(&msg).expect("json encode");
    let mut decoded = {
        let mut cur = std::io::Cursor::new(json_frame.clone());
        let mut carry = Vec::new();
        json_codec.read_frame(&mut cur, &mut carry).expect("json decode").expect("json frame")
    };
    assert_eq!(decoded.to_string(), msg.to_string(), "json wire round-trip drifted");

    match wire::codec_for(WireKind::Binary) {
        Ok(bin_codec) => {
            let bin_frame = bin_codec.encode(&msg).expect("binary encode");
            decoded = {
                let mut cur = std::io::Cursor::new(bin_frame.clone());
                let mut carry = Vec::new();
                bin_codec
                    .read_frame(&mut cur, &mut carry)
                    .expect("binary decode")
                    .expect("binary frame")
            };
            assert_eq!(
                decoded.to_string(),
                msg.to_string(),
                "binary wire round-trip drifted from the json value"
            );
            println!(
                "  wire codecs: 1024-float submit frame {} bytes binary vs {} bytes json \
                 ({:.2}x smaller)",
                bin_frame.len(),
                json_frame.len(),
                json_frame.len() as f64 / bin_frame.len().max(1) as f64
            );
            assert!(
                bin_frame.len() < json_frame.len(),
                "binary frame ({} bytes) not below json ({} bytes) on a float-heavy payload",
                bin_frame.len(),
                json_frame.len()
            );
        }
        Err(_) => println!("  wire codecs: built without `wire-binary`; byte gate skipped"),
    }

    // -------- saturated-queue rejection --------
    let dir = std::env::temp_dir().join(format!("edc_bench_wire_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let svc = Service::start(ServeConfig {
        dir: dir.clone(),
        max_concurrent_jobs: 1,
        max_queue_depth: 1,
        max_inflight_per_conn: 64,
        ..ServeConfig::default()
    })
    .expect("daemon failed to start");
    let mut client = Client::connect(&svc.addr().to_string()).expect("connect");
    let submit_body = |seed: &str, episodes: f64| {
        let mut j = Json::obj();
        j.set("net", Json::Str("lenet5".into()))
            .set("seeds", Json::Num(1.0))
            .set("episodes", Json::Num(episodes))
            .set("chunk", Json::Num(1.0))
            .set("steps", Json::Num(6.0))
            .set("seed", Json::Str(seed.into()))
            .set("dataflows", Json::Str("X:Y".into()));
        j
    };
    // Fill the one runner slot, wait until the job leaves the queue,
    // then fill the queue itself.
    let running = client.submit(&submit_body("97", 6.0)).expect("submit running");
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(600);
    loop {
        let s = client.status(Some(running)).expect("status");
        if s.str_or("state", "") == "running" {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "first job never started");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let queued = client.submit(&submit_body("98", 1.0)).expect("submit queued");

    const REJECTS: usize = 50;
    let mut overflow = submit_body("99", 1.0);
    overflow.set("cmd", Json::Str("submit".into()));
    let t0 = std::time::Instant::now();
    for i in 0..REJECTS {
        let resp = client.request(&overflow).expect("overflow request");
        assert_eq!(
            resp.str_or("code", ""),
            "busy",
            "overflow submit #{i} was not a typed busy rejection: {resp}"
        );
        assert!(resp.num_or("retry_after_ms", 0.0) > 0.0, "no retry hint: {resp}");
    }
    let t_reject = t0.elapsed();
    println!(
        "  backpressure: {REJECTS} saturated submits rejected in {t_reject:?} \
         ({:.0}us each), running job undisturbed",
        t_reject.as_secs_f64() * 1e6 / REJECTS as f64
    );
    // O(1) per rejection: the bound is generous (CI boxes are noisy)
    // but categorically below what any queue-scan or job-stall costs.
    assert!(
        t_reject < std::time::Duration::from_millis(2500),
        "{REJECTS} rejections took {t_reject:?}; admission control must be O(1) when saturated"
    );
    let long = std::time::Duration::from_secs(600);
    assert_eq!(
        client.wait_done(running, long).expect("running job").str_or("state", ""),
        "done",
        "the rejected burst stalled the running job"
    );
    assert_eq!(
        client.wait_done(queued, long).expect("queued job").str_or("state", ""),
        "done"
    );
    client.shutdown().expect("shutdown");
    svc.wait().expect("daemon drain");
    std::fs::remove_dir_all(&dir).ok();
}

/// The PR-10 router claims (CI gate), in two halves.
///
/// **Bounded proxy overhead:** a per-job status round-trip proxied
/// through `edc route` (fresh backend dial + forwarded request + reply
/// rewrite) must stay within a generous constant factor of the same
/// request sent directly to the backend — the router adds a hop, never
/// a health probe, a lock convoy or a hang on the request path.
///
/// **Failover acceptance:** with one of two backends killed and
/// quarantined, a burst of submits through the router must be accepted
/// at the surviving backend's own rate (within scheduling noise). The
/// breaker keeps the dead sibling out of the candidate set entirely;
/// if every submit re-dialed the corpse, each accept would eat a
/// connect timeout and this gate would blow up by orders of magnitude.
fn bench_router_overhead_and_failover() {
    use edcompress::coordinator::router::{Router, RouterConfig};
    use edcompress::coordinator::service::{Client, ServeConfig, Service};
    use edcompress::util::json::Json;
    use std::time::{Duration, Instant};

    let base = std::env::temp_dir().join(format!("edc_bench_route_{}", std::process::id()));
    std::fs::remove_dir_all(&base).ok();
    let backend = |sub: &str| {
        Service::start(ServeConfig {
            dir: base.join(sub),
            max_concurrent_jobs: 1,
            ..ServeConfig::default()
        })
        .expect("backend daemon failed to start")
    };
    let svc0 = backend("b0");
    let svc1 = backend("b1");
    let svc1_addr = svc1.addr().to_string();
    let router = Router::start(RouterConfig {
        dir: base.join("route"),
        backends: vec![svc0.addr().to_string(), svc1_addr.clone()],
        breaker_threshold: 1,
        health_period: Duration::from_millis(50),
        probe_base: Duration::from_millis(100),
        probe_cap: Duration::from_millis(400),
        ..RouterConfig::default()
    })
    .expect("router failed to start");

    let tiny = |seed: &str| {
        let mut j = Json::obj();
        j.set("net", Json::Str("lenet5".into()))
            .set("seeds", Json::Num(1.0))
            .set("episodes", Json::Num(1.0))
            .set("chunk", Json::Num(1.0))
            .set("steps", Json::Num(4.0))
            .set("seed", Json::Str(seed.into()))
            .set("dataflows", Json::Str("X:Y".into()));
        j
    };
    let long = Duration::from_secs(600);
    let mut routed = Client::connect(&router.addr().to_string()).expect("connect router");
    let mut d0 = Client::connect(&svc0.addr().to_string()).expect("connect backend 0");
    let mut d1 = Client::connect(&svc1_addr).expect("connect backend 1");

    // -------- bounded proxy overhead --------
    // One tiny job through the router (both backends idle, so the
    // index tie-break lands it on backend 0), run to completion; its
    // per-job status then exercises the full proxy path every time.
    let rid = routed.submit(&tiny("41")).expect("routed submit");
    let s = routed.wait_done(rid, long).expect("routed job");
    assert_eq!(s.str_or("state", ""), "done", "routed job failed: {s}");
    let backend_job = {
        let s = d0.status(None).expect("backend status");
        let jobs = s.get("jobs").and_then(|a| a.as_arr()).expect("jobs array");
        assert_eq!(jobs.len(), 1, "the routed job must land on backend 0");
        jobs[0].num_or("id", 0.0) as u64
    };

    const REQS: u32 = 30;
    d0.status(Some(backend_job)).expect("warm direct");
    routed.status(Some(rid)).expect("warm routed");
    let t0 = Instant::now();
    for _ in 0..REQS {
        let s = d0.status(Some(backend_job)).expect("direct status");
        assert_eq!(s.str_or("state", ""), "done");
    }
    let t_direct = t0.elapsed();
    let t0 = Instant::now();
    for _ in 0..REQS {
        let s = routed.status(Some(rid)).expect("routed status");
        assert_eq!(s.str_or("state", ""), "done");
    }
    let t_routed = t0.elapsed();
    println!(
        "  router overhead: {REQS} proxied status round-trips {t_routed:?} vs direct \
         {t_direct:?} ({:.1}x)",
        t_routed.as_secs_f64() / t_direct.as_secs_f64().max(1e-9)
    );
    let bound = t_direct * 25 + Duration::from_millis(750);
    assert!(
        t_routed < bound,
        "proxied status {t_routed:?} above the overhead bound {bound:?} (direct {t_direct:?})"
    );

    // -------- failover acceptance rate --------
    d0.shutdown().expect("backend 0 shutdown");
    svc0.wait().expect("backend 0 drain");
    let deadline = Instant::now() + long;
    loop {
        let s = routed.status(None).expect("router status");
        let backends = s.get("backends").and_then(|a| a.as_arr()).expect("backends");
        if backends[0].str_or("state", "") == "quarantined" {
            break;
        }
        assert!(Instant::now() < deadline, "backend 0 was never quarantined");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Same burst direct to the surviving backend, then through the
    // router with the dead sibling still in the fleet.
    let t0 = Instant::now();
    let direct_ids: Vec<u64> = (0..3)
        .map(|i| d1.submit(&tiny(&format!("5{i}"))).expect("direct submit"))
        .collect();
    let t_direct_accept = t0.elapsed();
    let t0 = Instant::now();
    let routed_ids: Vec<u64> = (0..3)
        .map(|i| routed.submit_with_retries(&tiny(&format!("6{i}")), 4).expect("routed submit"))
        .collect();
    let t_routed_accept = t0.elapsed();
    println!(
        "  failover: 3 routed submits accepted in {t_routed_accept:?} with a dead sibling \
         (direct single-backend burst {t_direct_accept:?})"
    );
    assert!(
        t_routed_accept < t_direct_accept + Duration::from_secs(1),
        "routed accepts {t_routed_accept:?} fell behind single-backend {t_direct_accept:?} + 1s"
    );
    for id in direct_ids {
        assert_eq!(d1.wait_done(id, long).expect("direct job").str_or("state", ""), "done");
    }
    for id in routed_ids {
        let s = routed.wait_done(id, long).expect("failover job");
        assert_eq!(s.str_or("state", ""), "done", "failover job did not finish: {s}");
        assert_eq!(
            s.str_or("backend", ""),
            svc1_addr,
            "failover submit was routed to the dead backend"
        );
    }

    routed.shutdown().expect("router shutdown");
    router.wait().expect("router drain");
    d1.shutdown().expect("backend 1 shutdown");
    svc1.wait().expect("backend 1 drain");
    std::fs::remove_dir_all(&base).ok();
}

/// The snapshot-container claim (CI gate): resuming a 16-seed fleet
/// snapshot from the v4 binary container must beat the v3 JSON container
/// on both resume wall-clock and peak live heap bytes, and the file
/// itself must be smaller. v3 pays for itself three times over — UTF-8
/// text, a `Json::Num` node per tensor element, then the f32 tensors —
/// while v4 parses only the small header tree and reads the aligned
/// sections as typed leaves. Resume runs single-threaded on this thread,
/// so the thread-local peak tracker sees its whole working set.
fn bench_snapshot_resume_formats(iters: usize) {
    use edcompress::coordinator::orchestrator::{Orchestrator, OrchestratorSpec};
    use edcompress::coordinator::SearchConfig;
    use edcompress::snapshot::Format;

    fn spec() -> OrchestratorSpec {
        let mut s = OrchestratorSpec::new(zoo::lenet5(), 16, 29);
        s.dataflows = vec![Dataflow::XY, Dataflow::FXFY];
        s.env.max_steps = 6;
        s.chunk_episodes = 1;
        s.search = SearchConfig {
            episodes: 2,
            sac: SacConfig {
                hidden: vec![32, 32],
                warmup_steps: 8,
                batch_size: 8,
                ..SacConfig::default()
            },
            verbose: false,
        };
        s
    }

    // One completed round so every slot carries real agent tensors,
    // optimizer moments and replay transitions — the payload a fleet
    // snapshot exists for.
    let mut orch = Orchestrator::new(spec());
    let done = orch.run_round().expect("fixture round failed");
    assert!(!done, "fixture must snapshot mid-run, not a finished search");

    let dir = std::env::temp_dir().join(format!("edc_bench_snapshot_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench temp dir");
    let p_v3 = dir.join("fleet.json");
    let p_v4 = dir.join("fleet.edc4");
    orch.save_snapshot_as(&p_v3, Format::Json).expect("v3 save");
    orch.save_snapshot_as(&p_v4, Format::Binary).expect("v4 save");
    let bytes_v3 = std::fs::metadata(&p_v3).expect("v3 meta").len();
    let bytes_v4 = std::fs::metadata(&p_v4).expect("v4 meta").len();

    let (_, peak_v3) = with_peak_tracking(|| {
        Orchestrator::resume(&p_v3, spec()).expect("v3 resume")
    });
    let (_, peak_v4) = with_peak_tracking(|| {
        Orchestrator::resume(&p_v4, spec()).expect("v4 resume")
    });

    let mut t_v3 = BenchTimer::new("fleet resume v3 JSON (16 seeds)");
    t_v3.run(iters, || Orchestrator::resume(&p_v3, spec()).expect("v3 resume"));
    t_v3.report();
    let mut t_v4 = BenchTimer::new("fleet resume v4 binary (16 seeds)");
    t_v4.run(iters, || Orchestrator::resume(&p_v4, spec()).expect("v4 resume"));
    t_v4.report();
    std::fs::remove_dir_all(&dir).ok();

    let speedup = t_v3.mean_ns() / t_v4.mean_ns().max(1.0);
    println!(
        "  -> v4 resume {speedup:.2}x faster; peak heap {peak_v4} B vs {peak_v3} B \
         ({:.2}x smaller); file {bytes_v4} B vs {bytes_v3} B on disk",
        peak_v3 as f64 / peak_v4.max(1) as f64
    );
    assert!(
        speedup >= 1.5,
        "v4 resume only {speedup:.2}x faster than v3 (gate: 1.5x)"
    );
    assert!(
        peak_v4 < peak_v3,
        "v4 resume peak heap {peak_v4} B not below v3's {peak_v3} B"
    );
    assert!(
        bytes_v4 < bytes_v3,
        "v4 snapshot {bytes_v4} B not smaller than v3's {bytes_v3} B"
    );
}

/// The async actor/learner throughput claim (CI gate): 8 LeNet-5 rollout
/// jobs multiplexed on a 4-slot pool, with SAC updates offloaded to
/// dedicated learner threads, must beat the synchronous engine — which
/// interleaves rollout and update on the same 4 slots — on episodes/sec.
///
/// The achievable speedup is (R+U)/max(R, U/L-ish) where R is rollout
/// wall, U is update wall and L the learner count: it comes entirely
/// from the extra learner threads overlapping update work with rollouts,
/// so it is hardware-bound. With >= 8 hardware threads the 1.5x gate is
/// asserted; below that both engines saturate the machine with identical
/// total work, the ratio hovers near 1.0 by construction, and only a
/// no-pathological-overhead floor is enforced.
fn bench_async_vs_sync_throughput() {
    use edcompress::coordinator::actor_learner::AsyncConfig;
    use edcompress::coordinator::orchestrator::{Orchestrator, OrchestratorSpec};
    use edcompress::coordinator::SearchConfig;
    use edcompress::util::pool::WorkPool;

    fn spec() -> OrchestratorSpec {
        let mut s = OrchestratorSpec::new(zoo::lenet5(), 8, 71);
        s.dataflows = vec![Dataflow::XY, Dataflow::FXFY];
        s.env.max_steps = 12;
        s.chunk_episodes = 4;
        s.search = SearchConfig {
            episodes: 8,
            sac: SacConfig {
                hidden: vec![32, 32],
                // Past warmup quickly, then two batch-32 updates per env
                // step: update work dominates, which is the regime the
                // learner offload is for.
                warmup_steps: 8,
                batch_size: 32,
                updates_per_step: 2,
                ..SacConfig::default()
            },
            verbose: false,
        };
        s
    }

    let episodes_total = (8 * 8) as f64;
    let pool = WorkPool::new(4);

    let mut sync_orch = Orchestrator::new(spec());
    let t0 = std::time::Instant::now();
    let sync_res = sync_orch.run_on(&pool).expect("sync run failed");
    let t_sync = t0.elapsed();
    assert!(sync_res.failures.is_empty(), "sync failures: {:?}", sync_res.failures);

    // Relaxed mode: 8 rollout jobs on the same 4 slots, 8 learners.
    let cfg = AsyncConfig::new(8, 8);
    assert!(!cfg.lockstep, "throughput gate must run the relaxed engine");
    let mut async_orch = Orchestrator::new(spec());
    let t0 = std::time::Instant::now();
    let async_res = async_orch.run_async_on(&pool, &cfg).expect("async run failed");
    let t_async = t0.elapsed();
    assert!(async_res.failures.is_empty(), "async failures: {:?}", async_res.failures);

    let eps_sync = episodes_total / t_sync.as_secs_f64().max(1e-9);
    let eps_async = episodes_total / t_async.as_secs_f64().max(1e-9);
    let speedup = eps_async / eps_sync.max(1e-9);
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "  async {eps_async:.1} eps/s vs sync {eps_sync:.1} eps/s -> {speedup:.2}x \
         (8 actors on 4 pool slots + 8 learners, {hw} hardware threads)"
    );
    if hw >= 8 {
        assert!(
            speedup >= 1.5,
            "async episodes/sec {speedup:.2}x below the 1.5x gate on {hw} hardware threads"
        );
    } else {
        println!("  (under 8 hardware threads: 1.5x scaling gate skipped, overhead floor only)");
        assert!(
            speedup >= 0.75,
            "async engine added pathological overhead: {speedup:.2}x on {hw} hardware threads"
        );
    }
}

fn bench_incremental_vs_full(net: &Network, df: Dataflow, cfg: &EnergyConfig, min_speedup: f64) {
    let steps = 32;
    let traj = episode_trajectory(net, steps);

    let mut t_full = BenchTimer::new(&format!("episode eval FULL {} {}", net.name, df.label()));
    t_full.run(60, || {
        let mut acc = 0.0;
        for s in &traj {
            acc += energy::evaluate(net, s, df, cfg).total_energy();
        }
        acc
    });
    t_full.report();

    // The incremental evaluator persists across episodes exactly like the
    // one inside CompressionEnv, so steady-state search iterations mostly
    // hit the layer cache.
    let mut ev = cache::IncrementalEvaluator::new(net, df, cfg);
    let mut t_inc = BenchTimer::new(&format!("episode eval INC {} {}", net.name, df.label()));
    t_inc.run(60, || {
        let mut acc = 0.0;
        for s in &traj {
            acc += ev.evaluate(net, s, cfg).0;
        }
        acc
    });
    t_inc.report();

    let speedup = t_full.mean_ns() / t_inc.mean_ns().max(1.0);
    println!(
        "  -> incremental speedup {:.1}x over full re-evaluation ({} steps, cache: {} hits / {} misses)",
        speedup,
        steps,
        ev.hits(),
        ev.misses()
    );
    // Acceptance gate: >= 5x on the steady-state episode for the
    // deep-network case (vgg16_cifar, where per-layer work dominates);
    // LeNet-5's 4 compute layers leave fixed per-step overhead on top,
    // so it carries a 3x floor rather than the headline gate.
    assert!(
        speedup >= min_speedup,
        "incremental evaluation speedup {speedup:.1}x below the {min_speedup}x target for {}",
        net.name
    );
}

fn bench_batch_vs_individual(net: &Network, cfg: &EnergyConfig) {
    let s = CompressionState::uniform(net, 6.0, 0.6);
    let dfs = Dataflow::all_fifteen();

    let mut t_ind = BenchTimer::new(&format!("rank 15 dataflows INDIVIDUAL {}", net.name));
    t_ind.run(50, || {
        let mut acc = 0.0;
        for &df in &dfs {
            acc += energy::evaluate(net, &s, df, cfg).total_energy();
        }
        acc
    });
    t_ind.report();

    let mut cost_cache = cache::CostCache::new(net, cfg);
    let mut t_batch = BenchTimer::new(&format!("rank 15 dataflows BATCH+cache {}", net.name));
    t_batch.run(50, || {
        energy::evaluate_batch(net, &s, &dfs, cfg, &mut cost_cache)
            .iter()
            .map(|r| r.total_energy())
            .sum::<f64>()
    });
    t_batch.report();
    println!(
        "  -> batch speedup {:.1}x over 15 individual evaluates",
        t_ind.mean_ns() / t_batch.mean_ns().max(1.0)
    );
}

fn main() {
    let cfg = EnergyConfig::default();
    // `--test` (CI smoke mode): only the asserted shared-cache fleet
    // comparison, small enough for every PR.
    if std::env::args().any(|a| a == "--test") {
        banner("train kernels (smoke)");
        bench_train_kernels(60);
        banner("fleet-shared cache (smoke)");
        bench_fleet_shared_vs_private(&zoo::vgg16_cifar(), Dataflow::XY, &cfg, 4, 16);
        banner("edc serve shared cache (smoke)");
        bench_serve_shared_vs_sequential();
        banner("async actor/learner throughput (smoke)");
        bench_async_vs_sync_throughput();
        banner("snapshot resume formats (smoke)");
        bench_snapshot_resume_formats(5);
        banner("wire codecs + backpressure (smoke)");
        bench_wire_codecs_and_backpressure();
        banner("router overhead + failover (smoke)");
        bench_router_overhead_and_failover();
        println!("bench smoke OK");
        return;
    }

    banner("L3 hot paths");

    // 1. Cost-model evaluation (called on every RL step in sweeps).
    for net in [zoo::lenet5(), zoo::vgg16_cifar(), zoo::mobilenet_v1()] {
        let s = CompressionState::uniform(&net, 6.0, 0.6);
        let mut t = BenchTimer::new(&format!("energy::evaluate {}", net.name));
        t.run(200, || energy::evaluate(&net, &s, Dataflow::XY, &cfg).total_energy());
        t.report();
    }

    // 2. Incremental engine vs full re-evaluation (this PR's hot-path
    // claim) on a small and a large network.
    banner("incremental engine");
    bench_incremental_vs_full(&zoo::lenet5(), Dataflow::XY, &cfg, 3.0);
    bench_incremental_vs_full(&zoo::vgg16_cifar(), Dataflow::CICO, &cfg, 5.0);

    // 3. Fleet-wide shared cache vs private per-seed caches (asserted).
    banner("fleet-shared cache");
    bench_fleet_shared_vs_private(&zoo::vgg16_cifar(), Dataflow::XY, &cfg, 4, 32);

    // 3b. The `edc serve` daemon path: concurrent same-network jobs on
    // one registry cache vs sequential standalone runs (asserted).
    banner("edc serve shared cache");
    bench_serve_shared_vs_sequential();

    // 3c. Async actor/learner engine vs the synchronous engine on
    // episodes/sec (asserted, hardware-gated).
    banner("async actor/learner throughput");
    bench_async_vs_sync_throughput();

    // 3d. Snapshot container formats: v4 binary resume vs v3 JSON on
    // wall-clock, peak heap bytes and file size (asserted).
    banner("snapshot resume formats");
    bench_snapshot_resume_formats(20);

    // 3e. Wire codec payload bytes and saturated-queue admission
    // control on the serve daemon (asserted).
    banner("wire codecs + backpressure");
    bench_wire_codecs_and_backpressure();

    // 3f. Router proxy overhead and accept-rate under a dead backend
    // (asserted).
    banner("router overhead + failover");
    bench_router_overhead_and_failover();

    // 4. All-15-dataflow ranking: batched+cached vs individual.
    banner("dataflow ranking");
    bench_batch_vs_individual(&zoo::vgg16_cifar(), &cfg);
    {
        let net = zoo::vgg16_cifar();
        let s = CompressionState::uniform(&net, 6.0, 0.6);
        let mut t = BenchTimer::new("rank_dataflows vgg16 (15 dataflows)");
        t.run(50, || {
            edcompress::coordinator::sweep::rank_dataflows(&net, &s, &cfg)
        });
        t.report();
    }

    // 5. GEMM kernel (SAC's inner loop).
    banner("RL substrate");
    {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[64, 166], 1.0, &mut rng);
        let b = Tensor::randn(&[166, 128], 1.0, &mut rng);
        let mut t = BenchTimer::new("tensor::matmul 64x166x128");
        t.run(300, || a.matmul(&b));
        t.report();
    }

    // 6. SAC training kernels at LeNet env dimensions: scratch vs the
    // allocating reference, with the 2x + zero-alloc gates.
    bench_train_kernels(150);
    {
        let net = zoo::lenet5();
        let oracle = SurrogateOracle::new(&net, 0);
        let mut env = CompressionEnv::new(
            net,
            Dataflow::XY,
            Box::new(oracle),
            EnvConfig::default(),
            cfg.clone(),
        );
        let mut t = BenchTimer::new("CompressionEnv::step (surrogate)");
        let action = vec![-0.2; env.action_dim()];
        env.reset();
        t.run(200, || {
            let (_s, _r, done) = env.step(&action);
            if done {
                env.reset();
            }
        });
        t.report();
    }

    // 7. PJRT execute round-trip (skipped without artifacts).
    if edcompress::runtime::artifacts_available("lenet5") {
        use edcompress::runtime::{literal, Runtime};
        let rt = Runtime::cpu().expect("pjrt");
        let art = rt
            .load_artifact(&edcompress::runtime::artifacts_dir().join("kernel_fq.hlo.txt"))
            .expect("artifact");
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[32, 128], 1.0, &mut rng);
        let mut t = BenchTimer::new("PJRT kernel_fq execute (32x128)");
        t.run(100, || {
            let inputs = vec![
                literal::tensor_to_literal(&w).unwrap(),
                literal::scalar_literal(7.0),
                literal::scalar_literal(0.1),
            ];
            art.run(&inputs).unwrap()
        });
        t.report();
    } else {
        println!("PJRT bench skipped: artifacts missing (make artifacts)");
    }
}
