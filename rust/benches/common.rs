//! Mini benchmark harness shared by all bench targets (`criterion` is not
//! available offline; `cargo bench` runs these with `harness = false`).
//!
//! Conventions: each bench regenerates its paper table/figure (printing
//! it, deliverable (d)) and reports wall-clock timing statistics for the
//! work involved. `EDC_EPISODES` scales the search budget (default kept
//! small so `cargo bench` completes in minutes; EXPERIMENTS.md records
//! the 60-episode runs).

use std::time::Instant;

pub struct BenchTimer {
    name: String,
    samples_ns: Vec<f64>,
}

impl BenchTimer {
    pub fn new(name: &str) -> BenchTimer {
        BenchTimer {
            name: name.to_string(),
            samples_ns: Vec::new(),
        }
    }

    /// Time `iters` runs of `f`, discarding the first (warmup).
    pub fn run<T>(&mut self, iters: usize, mut f: impl FnMut() -> T) {
        for i in 0..iters + 1 {
            let t0 = Instant::now();
            let out = f();
            let ns = t0.elapsed().as_nanos() as f64;
            std::hint::black_box(&out);
            if i > 0 {
                self.samples_ns.push(ns);
            }
        }
    }

    /// Mean sample time in nanoseconds (0 before any `run`).
    pub fn mean_ns(&self) -> f64 {
        let n = self.samples_ns.len().max(1) as f64;
        self.samples_ns.iter().sum::<f64>() / n
    }

    pub fn report(&self) {
        let mean = self.mean_ns();
        let mut sorted = self.samples_ns.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = sorted.get(sorted.len() / 2).copied().unwrap_or(0.0);
        let min = sorted.first().copied().unwrap_or(0.0);
        println!(
            "bench {:<40} mean {:>12} p50 {:>12} min {:>12} (n={})",
            self.name,
            fmt_ns(mean),
            fmt_ns(p50),
            fmt_ns(min),
            self.samples_ns.len()
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Episode budget for bench-time searches.
pub fn bench_episodes() -> usize {
    std::env::var("EDC_EPISODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12)
}

/// Standard bench prologue.
pub fn banner(what: &str) {
    println!("\n=== {what} ===");
}
