//! Regenerates Table 2 (EDCompress vs HAQ, MobileNet) and times the
//! end-to-end search per dataflow.
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::tables;

fn main() {
    banner("Table 2: EDCompress vs HAQ (MobileNet)");
    let eps = bench_episodes();
    let mut t = BenchTimer::new(&format!("table2 search ({eps} episodes x 4 dataflows)"));
    let mut rendered = String::new();
    t.run(1, || {
        let (table, _outs) = tables::table2(eps, 0);
        rendered = table.render();
    });
    println!("{rendered}");
    t.report();
}
