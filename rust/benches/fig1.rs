//! Regenerates Figure 1 (EDC vs DC: compression rate vs energy/area eff).
#[path = "common.rs"]
mod common;
use common::{banner, bench_episodes, BenchTimer};
use edcompress::report::figures;

fn main() {
    banner("Figure 1: EDC vs Deep Compression");
    let eps = bench_episodes();
    let mut t = BenchTimer::new("fig1 (LeNet sweep + DC eval)");
    let mut rendered = String::new();
    t.run(1, || rendered = figures::fig1(eps, 0).render());
    println!("{rendered}");
    t.report();
}
