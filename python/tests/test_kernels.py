"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, bit depths and prune fractions — the CORE
correctness signal for the compute layer (the same quantization grid is
pinned on the Rust side by `rust/src/compress/quant.rs`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fake_quant_pallas,
    quant_conv2d_pallas,
    quant_matmul_pallas,
    ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def lvl_of(bits: int) -> jnp.ndarray:
    return jnp.float32(ref.levels(bits))


def thresh_for(w, remaining: float) -> jnp.ndarray:
    """Magnitude threshold keeping ~remaining of the weights."""
    if remaining >= 1.0:
        return jnp.float32(0.0)
    mags = np.sort(np.abs(np.asarray(w)).ravel())[::-1]
    keep = max(1, int(round(len(mags) * remaining)))
    return jnp.float32(mags[keep - 1])


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 70),
    cols=st.integers(1, 70),
    bits=st.integers(2, 8),
    remaining=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_matches_ref(rows, cols, bits, remaining, seed):
    w = rand(seed, (rows, cols))
    lvl = lvl_of(bits)
    t = thresh_for(w, remaining)
    got = fake_quant_pallas(w, lvl, t)
    want = ref.fake_quant(w, lvl, t)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    rank=st.integers(1, 4),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_arbitrary_rank(rank, bits, seed):
    dims = tuple(np.random.RandomState(seed).randint(1, 9, size=rank))
    w = rand(seed, dims)
    got = fake_quant_pallas(w, lvl_of(bits), jnp.float32(0.0))
    want = ref.fake_quant(w, lvl_of(bits), jnp.float32(0.0))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_fake_quant_idempotent():
    w = rand(0, (33, 17))
    lvl = lvl_of(4)
    q1 = fake_quant_pallas(w, lvl, jnp.float32(0.0))
    q2 = fake_quant_pallas(q1, lvl, jnp.float32(0.0))
    np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-6)


def test_fake_quant_prunes_small_weights():
    w = jnp.array([[0.01, -0.5], [0.02, 0.9]], jnp.float32)
    out = np.asarray(fake_quant_pallas(w, lvl_of(8), jnp.float32(0.1)))
    assert out[0, 0] == 0.0 and out[1, 0] == 0.0
    assert out[0, 1] != 0.0 and out[1, 1] != 0.0


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 33),
    k=st.integers(1, 48),
    n=st.integers(1, 150),
    bits=st.integers(2, 8),
    remaining=st.floats(0.2, 1.0),
    seed=st.integers(0, 2**16),
)
def test_quant_matmul_matches_ref(m, k, n, bits, remaining, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    lvl = lvl_of(bits)
    t = thresh_for(w, remaining)
    got = quant_matmul_pallas(x, w, lvl, t)
    want = ref.quant_matmul(x, w, lvl, t)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_quant_matmul_full_precision_is_plain_matmul():
    x = rand(3, (4, 8))
    w = rand(4, (8, 6))
    got = quant_matmul_pallas(x, w, jnp.float32(2**20), jnp.float32(0.0))
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# quant_conv2d
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    hw=st.integers(6, 16),
    ci=st.integers(1, 6),
    co=st.integers(1, 12),
    f=st.sampled_from([1, 3, 5]),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_quant_conv2d_matches_ref(b, hw, ci, co, f, bits, seed):
    x = rand(seed, (b, hw, hw, ci))
    w = rand(seed + 1, (f, f, ci, co))
    lvl = lvl_of(bits)
    t = thresh_for(w, 0.7)
    got = quant_conv2d_pallas(x, w, lvl, t)
    want = ref.quant_conv2d(x, w, lvl, t)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# STE gradients
# ---------------------------------------------------------------------------
def test_ste_gradient_passes_through_survivors():
    w = jnp.array([0.5, -0.8, 0.01], jnp.float32)
    t = jnp.float32(0.1)

    def f(w):
        return jnp.sum(ref.fake_quant_ste(w, lvl_of(4), t) * jnp.array([1.0, 2.0, 3.0]))

    g = jax.grad(f)(w)
    # Survivors get the straight-through gradient; pruned weight gets 0.
    np.testing.assert_allclose(g, [1.0, 2.0, 0.0], atol=1e-6)


def test_ste_forward_equals_fake_quant():
    w = rand(9, (20,))
    lvl = lvl_of(3)
    t = jnp.float32(0.2)
    np.testing.assert_allclose(
        ref.fake_quant_ste(w, lvl, t), ref.fake_quant(w, lvl, t), atol=1e-6
    )


def test_quant_error_shrinks_with_bits():
    w = rand(11, (64, 64))
    errs = []
    for bits in (2, 4, 8):
        q = ref.fake_quant(w, lvl_of(bits), jnp.float32(0.0))
        errs.append(float(jnp.mean((q - w) ** 2)))
    assert errs[0] > errs[1] > errs[2]


# ---------------------------------------------------------------------------
# Layer wrappers (Pallas fwd + STE bwd agree with pure-ref autodiff)
# ---------------------------------------------------------------------------
def test_quant_dense_gradients_match_ref():
    from compile.models import layers

    x = rand(21, (4, 10))
    w = rand(22, (10, 7))
    lvl, t = lvl_of(4), jnp.float32(0.05)

    def loss_pallas(w):
        return jnp.sum(layers.quant_dense(x, w, lvl, t) ** 2)

    def loss_ref(w):
        return jnp.sum((x @ ref.fake_quant_ste(w, lvl, t)) ** 2)

    g1 = jax.grad(loss_pallas)(w)
    g2 = jax.grad(loss_ref)(w)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-3)


def test_quant_conv_gradients_match_ref():
    from compile.models import layers

    x = rand(31, (2, 8, 8, 3))
    w = rand(32, (3, 3, 3, 5))
    lvl, t = lvl_of(5), jnp.float32(0.05)

    def loss_pallas(w):
        return jnp.sum(layers.quant_conv(x, w, lvl, t) ** 2)

    def loss_ref(w):
        wq = ref.fake_quant_ste(w, lvl, t)
        out = jax.lax.conv_general_dilated(
            x, wq, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return jnp.sum(out**2)

    g1 = jax.grad(loss_pallas)(w)
    g2 = jax.grad(loss_ref)(w)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-3)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
