"""AOT pipeline: HLO-text emission sanity.

Full lowering of all three networks takes minutes; here we lower the
standalone kernel artifact plus LeNet's infer graph and validate the HLO
text structure (the Rust integration tests exercise actual execution).
"""

import os
import tempfile

import jax
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_kernel_demo_emits_parsable_hlo():
    with tempfile.TemporaryDirectory() as d:
        aot.emit_kernel_demo(d)
        path = os.path.join(d, "kernel_fq.hlo.txt")
        text = open(path).read()
        assert "HloModule" in text
        assert "ENTRY" in text


def test_lenet_infer_lowering():
    mod = M.NETWORKS["lenet5"]
    infer = M.make_infer(mod)
    lowered = jax.jit(infer).lower(*M.example_args("lenet5", train=False))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # Tuple return with (loss, acc).
    assert "ENTRY" in text


def test_meta_is_json_serializable():
    import json

    for name in M.NETWORKS:
        s = json.dumps(M.meta(name))
        back = json.loads(s)
        assert back["name"] == name
        assert back["batch"] == M.BATCH[name]


@pytest.mark.skipif(
    not os.path.isdir(os.path.join(os.path.dirname(__file__), "../../artifacts")),
    reason="artifacts not built",
)
def test_emitted_artifacts_present_and_wellformed():
    d = os.path.join(os.path.dirname(__file__), "../../artifacts")
    for name in M.NETWORKS:
        for kind in ("infer", "train"):
            p = os.path.join(d, f"{name}_{kind}.hlo.txt")
            if not os.path.exists(p):
                pytest.skip(f"{p} not built")
            head = open(p).read(4096)
            assert "HloModule" in head, p


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
