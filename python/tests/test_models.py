"""L2 correctness: model shapes, training dynamics, compression response."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def setup_net(name, batch=None):
    mod = M.NETWORKS[name]
    b = batch or 4
    h, w, c = mod.INPUT_SHAPE
    key = jax.random.PRNGKey(0)
    params = mod.init_params(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, h, w, c), jnp.float32)
    y = jnp.arange(b, dtype=jnp.int32) % mod.NUM_CLASSES
    lvls = jnp.full((mod.NUM_COMPUTE_LAYERS,), 127.0, jnp.float32)
    threshs = jnp.zeros((mod.NUM_COMPUTE_LAYERS,), jnp.float32)
    return mod, params, x, y, lvls, threshs


@pytest.mark.parametrize("name", list(M.NETWORKS))
def test_forward_shapes(name):
    mod, params, x, y, lvls, threshs = setup_net(name)
    logits = mod.apply(params, x, lvls, threshs)
    assert logits.shape == (x.shape[0], mod.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", list(M.NETWORKS))
def test_param_specs_match_init(name):
    mod = M.NETWORKS[name]
    params = mod.init_params(jax.random.PRNGKey(0))
    assert len(params) == len(mod.PARAM_SPECS)
    for p, (n, s) in zip(params, mod.PARAM_SPECS):
        assert p.shape == tuple(s), n
    # Weight count == compute-layer count (each compute layer has one _w).
    n_w = sum(1 for n, _ in mod.PARAM_SPECS if n.endswith("_w"))
    assert n_w == mod.NUM_COMPUTE_LAYERS


def test_lenet_loss_decreases_with_training():
    mod, params, x, y, lvls, threshs = setup_net("lenet5", batch=16)
    train = M.make_train_step(mod)
    losses = []
    p = list(params)
    for _ in range(8):
        out = train(x, y, lvls, threshs, jnp.float32(0.05), *p)
        losses.append(float(out[0]))
        p = list(out[2:])
    assert losses[-1] < losses[0] * 0.7, losses


def test_quantization_depth_changes_logits():
    mod, params, x, y, lvls, threshs = setup_net("lenet5")
    full = mod.apply(params, x, lvls, threshs)
    coarse = mod.apply(
        params, x, jnp.full_like(lvls, 1.0), threshs
    )  # 2-bit: 1 level
    assert float(jnp.max(jnp.abs(full - coarse))) > 1e-3


def test_pruning_threshold_zeroes_effect():
    mod, params, x, y, lvls, threshs = setup_net("lenet5")
    # Prune everything: logits become bias-only (identical across inputs
    # up to pooling of zeros).
    hard = jnp.full_like(threshs, 1e9)
    logits = mod.apply(params, x, lvls, hard)
    assert float(jnp.max(jnp.abs(logits[0] - logits[1]))) < 1e-5


def test_infer_matches_manual_loss():
    mod, params, x, y, lvls, threshs = setup_net("lenet5")
    infer = M.make_infer(mod)
    loss, acc = infer(x, y, lvls, threshs, *params)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0


def test_train_step_preserves_param_shapes():
    mod, params, x, y, lvls, threshs = setup_net("lenet5")
    train = M.make_train_step(mod)
    out = train(x, y, lvls, threshs, jnp.float32(0.01), *params)
    assert len(out) == 2 + len(params)
    for new, old in zip(out[2:], params):
        assert new.shape == old.shape


def test_example_args_are_consistent():
    for name in M.NETWORKS:
        infer_args = M.example_args(name, train=False)
        train_args = M.example_args(name, train=True)
        mod = M.NETWORKS[name]
        assert len(infer_args) == 4 + len(mod.PARAM_SPECS)
        assert len(train_args) == 5 + len(mod.PARAM_SPECS)
        meta = M.meta(name)
        assert meta["num_compute_layers"] == mod.NUM_COMPUTE_LAYERS
        assert len(meta["params"]) == len(mod.PARAM_SPECS)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
