"""Width-scaled VGG-16 for CIFAR-shaped inputs.

Same 13-conv/3-dense topology as the paper's VGG-16 (and as
`rust/src/model/zoo.rs::vgg16_cifar`, which drives the *energy* numbers
at full width); the executable artifact uses `WIDTH` = 0.25 so CPU-PJRT
fine-tuning stays tractable. Fine-tune dynamics only need a real
trainable network of the same topology (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers

WIDTH = 0.25
INPUT_SHAPE = (32, 32, 3)
NUM_CLASSES = 10

_PLAN = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]


def _ch(c: int) -> int:
    return max(8, int(c * WIDTH))


def param_specs():
    specs = []
    ci = 3
    for bi, (c, reps) in enumerate(_PLAN):
        co = _ch(c)
        for r in range(reps):
            specs.append((f"conv{bi + 1}_{r + 1}_w", (3, 3, ci, co)))
            specs.append((f"conv{bi + 1}_{r + 1}_b", (co,)))
            ci = co
    flat = _ch(512)  # 1x1 spatial after 5 pools
    fc_w = _ch(4096)
    specs.append(("fc6_w", (flat, fc_w)))
    specs.append(("fc6_b", (fc_w,)))
    specs.append(("fc7_w", (fc_w, fc_w)))
    specs.append(("fc7_b", (fc_w,)))
    specs.append(("fc8_w", (fc_w, NUM_CLASSES)))
    specs.append(("fc8_b", (NUM_CLASSES,)))
    return specs


PARAM_SPECS = param_specs()
NUM_COMPUTE_LAYERS = 16  # 13 convs + 3 dense


def init_params(key):
    params = []
    for name, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * (2.0 / fan_in) ** 0.5
            )
    return params


def apply(params, x, lvls, threshs):
    h = x
    pi = 0  # param index
    slot = 0  # compute-layer index
    for _bi, (_c, reps) in enumerate(_PLAN):
        for _r in range(reps):
            w, b = params[pi], params[pi + 1]
            pi += 2
            h = layers.quant_conv_same(h, w, lvls[slot], threshs[slot]) + b
            h = jax.nn.relu(h)
            slot += 1
        h = layers.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    for i in range(3):
        w, b = params[pi], params[pi + 1]
        pi += 2
        h = layers.quant_dense(h, w, lvls[slot], threshs[slot]) + b
        slot += 1
        if i < 2:
            h = jax.nn.relu(h)
    return h
