"""LeNet-5 (Caffe 20/50/500 variant — matching `rust/src/model/zoo.rs`)
with per-layer runtime compression inputs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers

# (name, shape) in parameter-list order. The Rust runtime reads the same
# order from the artifact's meta.json.
PARAM_SPECS = [
    ("conv1_w", (5, 5, 1, 20)),
    ("conv1_b", (20,)),
    ("conv2_w", (5, 5, 20, 50)),
    ("conv2_b", (50,)),
    ("fc1_w", (800, 500)),
    ("fc1_b", (500,)),
    ("fc2_w", (500, 10)),
    ("fc2_b", (10,)),
]

INPUT_SHAPE = (28, 28, 1)
NUM_CLASSES = 10
# Compute layers (carrying q/p state), in order: conv1, conv2, fc1, fc2.
NUM_COMPUTE_LAYERS = 4


def init_params(key):
    """He-initialized parameter list (build-time tests only; the Rust
    harness initializes its own weights with the same shapes)."""
    params = []
    for name, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * (2.0 / fan_in) ** 0.5
            )
    return params


def apply(params, x, lvls, threshs):
    """Forward pass. `lvls`/`threshs` are [4] vectors (one per compute
    layer) of quantization levels and prune thresholds."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params
    h = layers.quant_conv(x, c1w, lvls[0], threshs[0]) + c1b
    h = jax.nn.relu(h)
    h = layers.maxpool2(h)
    h = layers.quant_conv(h, c2w, lvls[1], threshs[1]) + c2b
    h = jax.nn.relu(h)
    h = layers.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = layers.quant_dense(h, f1w, lvls[2], threshs[2]) + f1b
    h = jax.nn.relu(h)
    return layers.quant_dense(h, f2w, lvls[3], threshs[3]) + f2b
