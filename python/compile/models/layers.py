"""L2 building blocks: compression-aware layers with STE gradients.

Forward passes run the L1 Pallas kernels (so they land in the AOT
artifact); backward passes are straight-through-estimator VJPs derived
from the jnp reference (`kernels/ref.py`) — the standard QAT construction
the paper's per-step fine-tuning needs.

Every op takes the *runtime* compression scalars (`lvl` = 2^(q-1)-1
levels, `thresh` = prune threshold) so a single compiled artifact serves
every (Q, P) state the Rust-side RL agent visits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ref
from ..kernels.fake_quant import fake_quant_pallas
from ..kernels.quant_conv2d import quant_conv2d_pallas
from ..kernels.quant_matmul import quant_matmul_pallas


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------
@jax.custom_vjp
def quant_dense(x, w, lvl, thresh):
    """x @ fq(mask(w)) via the Pallas matmul kernel."""
    return quant_matmul_pallas(x, w, lvl, thresh)


def _dense_fwd(x, w, lvl, thresh):
    return quant_dense(x, w, lvl, thresh), (x, w, lvl, thresh)


def _dense_bwd(res, g):
    x, w, lvl, thresh = res
    # STE: differentiate the reference with the quantizer treated as
    # identity on surviving weights (mask gates pruned ones).
    _, vjp = jax.vjp(lambda xx, ww: xx @ ref.fake_quant_ste(ww, lvl, thresh), x, w)
    dx, dw = vjp(g)
    return dx, dw, None, None


quant_dense.defvjp(_dense_fwd, _dense_bwd)


# ---------------------------------------------------------------------------
# Conv (VALID, stride 1 — LeNet-style)
# ---------------------------------------------------------------------------
@jax.custom_vjp
def quant_conv(x, w, lvl, thresh):
    """VALID conv2d via the Pallas conv kernel. NHWC x HWIO."""
    return quant_conv2d_pallas(x, w, lvl, thresh)


def _conv_fwd(x, w, lvl, thresh):
    return quant_conv(x, w, lvl, thresh), (x, w, lvl, thresh)


def _conv_bwd(res, g):
    x, w, lvl, thresh = res
    _, vjp = jax.vjp(
        lambda xx, ww: jax.lax.conv_general_dilated(
            xx,
            ref.fake_quant_ste(ww, lvl, thresh),
            window_strides=(1, 1),
            padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ),
        x,
        w,
    )
    dx, dw = vjp(g)
    return dx, dw, None, None


quant_conv.defvjp(_conv_fwd, _conv_bwd)


# ---------------------------------------------------------------------------
# SAME conv with stride (VGG / MobileNet pointwise + first conv)
# ---------------------------------------------------------------------------
from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def quant_conv_same(x, w, lvl, thresh, stride: int = 1):
    """SAME conv: pad, run the VALID Pallas kernel, subsample for stride.

    Stride-by-subsampling wastes MACs at build time but keeps a single
    kernel; artifacts are AOT so the request path never pays Python.
    """
    fh, fw = w.shape[0], w.shape[1]
    ph, pw = (fh - 1) // 2, (fw - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, fh - 1 - ph), (pw, fw - 1 - pw), (0, 0)))
    out = quant_conv2d_pallas(xp, w, lvl, thresh)
    if stride > 1:
        out = out[:, ::stride, ::stride, :]
    return out


def _conv_same_fwd(x, w, lvl, thresh, stride):
    return quant_conv_same(x, w, lvl, thresh, stride), (x, w, lvl, thresh)


def _conv_same_bwd(stride, res, g):
    x, w, lvl, thresh = res
    _, vjp = jax.vjp(
        lambda xx, ww: ref.quant_conv2d_same_ste(xx, ww, lvl, thresh, stride),
        x,
        w,
    )
    dx, dw = vjp(g)
    return dx, dw, None, None


quant_conv_same.defvjp(_conv_same_fwd, _conv_same_bwd)


# ---------------------------------------------------------------------------
# Depthwise SAME conv (MobileNet). The MAC pattern is grouped, which the
# matmul-shaped Pallas kernel does not cover; the weights still go through
# the Pallas fake-quant kernel so compression stays on the L1 path.
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(4,))
def quant_dwconv(x, w, lvl, thresh, stride: int = 1):
    wq = fake_quant_pallas(w, lvl, thresh)
    return jax.lax.conv_general_dilated(
        x,
        wq,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1],
    )


def _dw_fwd(x, w, lvl, thresh, stride):
    return quant_dwconv(x, w, lvl, thresh, stride), (x, w, lvl, thresh)


def _dw_bwd(stride, res, g):
    x, w, lvl, thresh = res
    _, vjp = jax.vjp(
        lambda xx, ww: jax.lax.conv_general_dilated(
            xx,
            ref.fake_quant_ste(ww, lvl, thresh),
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=xx.shape[-1],
        ),
        x,
        w,
    )
    dx, dw = vjp(g)
    return dx, dw, None, None


quant_dwconv.defvjp(_dw_fwd, _dw_bwd)


# ---------------------------------------------------------------------------
# Misc building blocks
# ---------------------------------------------------------------------------
def maxpool2(x):
    """2x2 max pooling, stride 2 (NHWC)."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def global_avgpool(x):
    """NHWC -> NC mean over spatial dims."""
    return jnp.mean(x, axis=(1, 2))


def cross_entropy(logits, labels, num_classes: int):
    """Mean softmax cross-entropy; labels int32 [B]."""
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
