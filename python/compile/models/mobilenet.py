"""Width-scaled MobileNet-v1 for CIFAR-shaped inputs (13 depthwise-
separable blocks, same topology as `rust/src/model/zoo.rs::mobilenet_cifar`)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers

WIDTH = 0.25
INPUT_SHAPE = (32, 32, 3)
NUM_CLASSES = 10

# (channels_out, stride) for the 13 blocks.
_PLAN = [
    (64, 1),
    (128, 2),
    (128, 1),
    (256, 2),
    (256, 1),
    (512, 2),
    (512, 1),
    (512, 1),
    (512, 1),
    (512, 1),
    (512, 1),
    (1024, 2),
    (1024, 1),
]


def _ch(c: int) -> int:
    return max(8, int(c * WIDTH))


def param_specs():
    specs = [("conv1_w", (3, 3, 3, _ch(32))), ("conv1_b", (_ch(32),))]
    ci = _ch(32)
    for i, (co, _stride) in enumerate(_PLAN):
        co = _ch(co)
        specs.append((f"dw{i + 1}_w", (3, 3, 1, ci)))  # depthwise HWIO: I=1, O=C
        specs.append((f"dw{i + 1}_b", (ci,)))
        specs.append((f"pw{i + 1}_w", (1, 1, ci, co)))
        specs.append((f"pw{i + 1}_b", (co,)))
        ci = co
    specs.append(("fc_w", (ci, NUM_CLASSES)))
    specs.append(("fc_b", (NUM_CLASSES,)))
    return specs


PARAM_SPECS = param_specs()
# conv1 + 13*(dw+pw) + fc = 28 compute layers.
NUM_COMPUTE_LAYERS = 28


def init_params(key):
    params = []
    for name, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * (2.0 / fan_in) ** 0.5
            )
    return params


def apply(params, x, lvls, threshs):
    # conv1, stride 2.
    h = layers.quant_conv_same(x, params[0], lvls[0], threshs[0], stride=2) + params[1]
    h = jax.nn.relu(h)
    pi, slot = 2, 1
    for _i, (_co, stride) in enumerate(_PLAN):
        dw_w, dw_b = params[pi], params[pi + 1]
        pw_w, pw_b = params[pi + 2], params[pi + 3]
        pi += 4
        h = layers.quant_dwconv(h, dw_w, lvls[slot], threshs[slot], stride=stride) + dw_b
        h = jax.nn.relu(h)
        slot += 1
        h = layers.quant_conv_same(h, pw_w, lvls[slot], threshs[slot]) + pw_b
        h = jax.nn.relu(h)
        slot += 1
    h = layers.global_avgpool(h)
    return layers.quant_dense(h, params[pi], lvls[slot], threshs[slot]) + params[pi + 1]
