"""L2 network definitions (quantization/pruning-aware)."""
