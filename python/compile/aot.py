"""AOT pipeline: lower every network's train/infer graph to HLO **text**.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--nets lenet5,...]

Outputs per network NAME:
    NAME_infer.hlo.txt   NAME_train.hlo.txt   NAME_meta.json
plus kernel_fq.hlo.txt (standalone fake-quant kernel, used by the
runtime round-trip integration test).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(fn, args, path: str) -> None:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text) / 1e6:.2f} MB)")


def emit_kernel_demo(out_dir: str) -> None:
    """Standalone Pallas fake-quant artifact for runtime smoke tests."""
    from .kernels.fake_quant import fake_quant_pallas

    spec = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    s = jax.ShapeDtypeStruct((), jnp.float32)

    def fn(w, lvl, thresh):
        return (fake_quant_pallas(w, lvl, thresh),)

    emit(fn, (spec, s, s), os.path.join(out_dir, "kernel_fq.hlo.txt"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--nets",
        default="lenet5,vgg16_cifar,mobilenet_cifar",
        help="comma-separated subset of networks",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    emit_kernel_demo(args.out_dir)

    for name in args.nets.split(","):
        name = name.strip()
        mod = model_lib.NETWORKS[name]
        infer = model_lib.make_infer(mod)
        train = model_lib.make_train_step(mod)
        emit(
            infer,
            model_lib.example_args(name, train=False),
            os.path.join(args.out_dir, f"{name}_infer.hlo.txt"),
        )
        emit(
            train,
            model_lib.example_args(name, train=True),
            os.path.join(args.out_dir, f"{name}_train.hlo.txt"),
        )
        with open(os.path.join(args.out_dir, f"{name}_meta.json"), "w") as f:
            json.dump(model_lib.meta(name), f, indent=1, sort_keys=True)
        print(f"wrote {name}_meta.json")


if __name__ == "__main__":
    main()
