"""L1 kernels: Pallas implementations + the pure-jnp reference oracle."""

from . import ref  # noqa: F401
from .fake_quant import fake_quant_pallas  # noqa: F401
from .quant_conv2d import quant_conv2d_pallas  # noqa: F401
from .quant_matmul import quant_matmul_pallas  # noqa: F401
