"""Pure-jnp reference oracle for every L1 Pallas kernel.

These are the ground truth the pytest + hypothesis suite checks the
kernels against (`python/tests/test_kernels.py`), and the math the Rust
cost/compression code mirrors (`rust/src/compress/quant.rs` pins the same
quantization grid).

Quantization scheme (symmetric uniform, matching the paper's q-bit integer
weights): with per-tensor max-abs ``m`` and ``L = 2^(q-1) - 1`` levels,

    fq(w) = round(clip(w, -m, m) / m * L) / L * m

Pruning (paper 3.1): magnitude threshold mask ``|w| >= t``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def levels(bits: int) -> float:
    """Positive quantization levels for a bit depth (>= 1)."""
    if bits <= 1:
        return 1.0
    return float(2 ** (bits - 1) - 1)


def prune_mask(w: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    """Binary mask keeping weights with |w| >= thresh."""
    return (jnp.abs(w) >= thresh).astype(w.dtype)


def fake_quant(w: jnp.ndarray, lvl: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    """Mask + symmetric uniform fake-quantization.

    ``lvl`` and ``thresh`` are scalars (dynamic inputs of the AOT graph, so
    one compiled artifact serves every compression state).
    """
    masked = w * prune_mask(w, thresh)
    m = jnp.maximum(jnp.max(jnp.abs(masked)), 1e-12)
    scaled = jnp.clip(jnp.round(masked / m * lvl), -lvl, lvl)
    return scaled / lvl * m


def fake_quant_ste(w, lvl, thresh):
    """Fake-quant with a straight-through estimator for training.

    Forward value equals :func:`fake_quant`; the gradient passes through
    the quantizer but is blocked on pruned weights (mask gating), the
    standard QAT construction the multi-step fine-tuning relies on.
    """
    mask = prune_mask(w, thresh)
    wm = w * mask
    q = fake_quant(w, lvl, thresh)
    return wm + jax.lax.stop_gradient(q - wm)


def quant_matmul(x, w, lvl, thresh):
    """x @ fq(w) — the dense-layer hot path."""
    return x @ fake_quant(w, lvl, thresh)


def quant_conv2d(x, w, lvl, thresh):
    """NHWC 'valid' conv with fake-quantized HWIO weights."""
    wq = fake_quant(w, lvl, thresh)
    return jax.lax.conv_general_dilated(
        x,
        wq,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def quant_conv2d_same(x, w, lvl, thresh, stride: int = 1):
    """NHWC 'same' conv (stride configurable) with quantized weights."""
    wq = fake_quant(w, lvl, thresh)
    return jax.lax.conv_general_dilated(
        x,
        wq,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def quant_conv2d_same_ste(x, w, lvl, thresh, stride: int = 1):
    """'same' conv with STE-quantized weights (training-path reference)."""
    wq = fake_quant_ste(w, lvl, thresh)
    return jax.lax.conv_general_dilated(
        x,
        wq,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def quant_dwconv2d_same(x, w, lvl, thresh, stride: int = 1):
    """Depthwise 'same' conv, HWIO with I=1, feature_group_count=C."""
    wq = fake_quant(w, lvl, thresh)
    c = x.shape[-1]
    return jax.lax.conv_general_dilated(
        x,
        wq,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
