"""L1 Pallas kernel: dense layer with in-kernel weight compression.

``y = x @ fq(mask(w))`` in one fused kernel — the FC hot path of the
paper's networks (LeNet-5's fc1 is 69% of its parameters). The kernel is
tiled for the MXU: the grid walks (M/BM, N/BN) output tiles, each program
reads an x-stripe [BM, K] and a w-stripe [K, BN] into VMEM, compresses
the weight stripe on the fly and issues one ``jnp.dot``
(``preferred_element_type=f32`` → MXU-eligible).

Keeping compression *inside* the matmul kernel means the q/p state the RL
agent picks at runtime flows into the same artifact — no recompilation
per compression step, which is what makes the Rust-side multi-step loop
possible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 8
BN = 128


def _kernel(x_ref, w_ref, scale_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    m = scale_ref[0]
    lvl = scale_ref[1]
    thresh = scale_ref[2]
    mask = (jnp.abs(w) >= thresh).astype(w.dtype)
    wm = w * mask
    wq = jnp.clip(jnp.round(wm / m * lvl), -lvl, lvl) / lvl * m
    o_ref[...] = jnp.dot(x, wq, preferred_element_type=jnp.float32)


def quant_matmul_pallas(x: jnp.ndarray, w: jnp.ndarray, lvl, thresh) -> jnp.ndarray:
    """Fused mask+quant+matmul. x: [M, K], w: [K, N] -> [M, N].

    Pads M to BM and N to BN so arbitrary layer widths are supported;
    the max-abs scale is computed over the *unpadded* weights outside.
    """
    mdim, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} vs {k2}"

    masked = w * (jnp.abs(w) >= thresh)
    mx = jnp.maximum(jnp.max(jnp.abs(masked)), 1e-12)
    scale = jnp.stack([mx, lvl, thresh]).astype(x.dtype)

    mp = ((mdim + BM - 1) // BM) * BM
    np_ = ((n + BN - 1) // BN) * BN
    xp = jnp.pad(x, ((0, mp - mdim), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, np_ - n)))

    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // BM, np_ // BN),
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        interpret=True,
    )(xp, wp, scale)
    return out[:mdim, :n]
