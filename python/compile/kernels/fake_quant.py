"""L1 Pallas kernel: fused prune-mask + symmetric fake-quantization.

The elementwise hot path of every compressed layer. TPU-shaped even under
``interpret=True``: the tensor is flattened and tiled into (8, 128)
VREG-aligned blocks (lane dim 128, sublane 8), the compression parameters
(quantization levels, prune threshold, max-abs scale) ride along as tiny
operands broadcast to every grid step.

The global max-abs is computed *outside* the kernel (a cheap jnp reduce
that XLA fuses) because a grid-tiled kernel cannot see the whole tensor;
the kernel is the per-element quantize/mask work.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VREG-aligned tile: 8 sublanes x 128 lanes.
BLOCK_ROWS = 8
BLOCK_COLS = 128
BLOCK = BLOCK_ROWS * BLOCK_COLS


def _kernel(w_ref, scale_ref, o_ref):
    """One (8, 128) tile: mask, scale to the grid, round, rescale.

    scale_ref holds [max_abs, levels, thresh] broadcast to each step.
    """
    w = w_ref[...]
    m = scale_ref[0]
    lvl = scale_ref[1]
    thresh = scale_ref[2]
    mask = (jnp.abs(w) >= thresh).astype(w.dtype)
    wm = w * mask
    scaled = jnp.clip(jnp.round(wm / m * lvl), -lvl, lvl)
    o_ref[...] = scaled / lvl * m


def fake_quant_pallas(w: jnp.ndarray, lvl: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    """Pallas-accelerated fake-quant of an arbitrary-shape tensor.

    Matches ``ref.fake_quant`` bit-for-bit (same grid, same clipping).
    """
    orig_shape = w.shape
    n = w.size
    flat = w.reshape(-1)
    # Pad to a whole number of (8,128) tiles.
    padded = ((n + BLOCK - 1) // BLOCK) * BLOCK
    if padded != n:
        flat = jnp.concatenate([flat, jnp.zeros(padded - n, w.dtype)])
    tiles = padded // BLOCK
    grid_w = flat.reshape(tiles * BLOCK_ROWS, BLOCK_COLS)

    masked = flat[:n] * (jnp.abs(flat[:n]) >= thresh)
    m = jnp.maximum(jnp.max(jnp.abs(masked)), 1e-12)
    scale = jnp.stack([m, lvl, thresh]).astype(w.dtype)

    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(grid_w.shape, w.dtype),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
            # The 3-vector of scalars is replicated to every grid step.
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
        interpret=True,
    )(grid_w, scale)
    return out.reshape(-1)[:n].reshape(orig_shape)
