"""L1 Pallas kernel: VALID conv2d with in-kernel weight compression.

The conv hot path (LeNet-5's conv2 is 70% of its MACs). GPU conv kernels
tile over threadblocks of output pixels; the TPU re-think (DESIGN.md
Hardware-Adaptation) lowers the filter taps as FH*FW shifted **matmuls**:
for each tap (fy, fx) the [H'*W', CI] input slab multiplies the [CI, CO]
weight slice on the MXU, accumulating in VMEM. The grid walks the batch;
each program holds one image slab + the whole (compressed) filter in
VMEM — for the paper's layer sizes that is well under the ~16 MiB budget.

The tap loop is a *Python* loop over static FH, FW, so it unrolls at
trace time into FH*FW dots — exactly the unrolled-loop structure the
paper's Algorithm 1 dataflow discussion is about.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(fh: int, fw: int, ho: int, wo: int):
    def kernel(x_ref, w_ref, scale_ref, o_ref):
        m = scale_ref[0]
        lvl = scale_ref[1]
        thresh = scale_ref[2]
        w = w_ref[...]  # [FH, FW, CI, CO]
        mask = (jnp.abs(w) >= thresh).astype(w.dtype)
        wm = w * mask
        wq = jnp.clip(jnp.round(wm / m * lvl), -lvl, lvl) / lvl * m

        x = x_ref[...]  # [1, H, W, CI]
        ci = x.shape[-1]
        co = wq.shape[-1]
        acc = jnp.zeros((ho * wo, co), jnp.float32)
        for fy in range(fh):  # static unroll: FH*FW MXU dots
            for fx in range(fw):
                slab = x[0, fy : fy + ho, fx : fx + wo, :].reshape(ho * wo, ci)
                acc += jnp.dot(
                    slab, wq[fy, fx], preferred_element_type=jnp.float32
                )
        o_ref[...] = acc.reshape(1, ho, wo, co)

    return kernel


def quant_conv2d_pallas(x: jnp.ndarray, w: jnp.ndarray, lvl, thresh) -> jnp.ndarray:
    """Fused mask+quant+conv2d (VALID, stride 1).

    x: [B, H, W, CI] NHWC; w: [FH, FW, CI, CO] HWIO -> [B, H', W', CO].
    """
    b, h, wdim, ci = x.shape
    fh, fw, ci2, co = w.shape
    assert ci == ci2, f"channel mismatch {ci} vs {ci2}"
    ho, wo = h - fh + 1, wdim - fw + 1

    masked = w * (jnp.abs(w) >= thresh)
    mx = jnp.maximum(jnp.max(jnp.abs(masked)), 1e-12)
    scale = jnp.stack([mx, lvl, thresh]).astype(x.dtype)

    return pl.pallas_call(
        _make_kernel(fh, fw, ho, wo),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, co), jnp.float32),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, wdim, ci), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((fh, fw, ci, co), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, co), lambda i: (i, 0, 0, 0)),
        interpret=True,
    )(x, w, scale)
