"""L2 assembly: train-step and inference graphs per network.

Each network exports two jittable functions over *flat* argument lists
(PJRT executes positional buffers; the Rust runtime mirrors the order,
which is also recorded in the artifact's ``meta.json``):

    infer(x, y, lvls, threshs, *params)        -> (loss, acc)
    train_step(x, y, lvls, threshs, lr, *params) -> (loss, acc, *new_params)

``lvls[l] = 2^(q_l - 1) - 1`` and ``threshs[l]`` are the runtime
compression state (Eq. 1 of the paper, materialized); ``train_step`` is
one SGD step with STE gradients — the Rust coordinator loops it for the
per-RL-step fine-tune budget.
"""

from __future__ import annotations

from .models import layers, lenet, mobilenet, vgg

NETWORKS = {
    "lenet5": lenet,
    "vgg16_cifar": vgg,
    "mobilenet_cifar": mobilenet,
}

# Executable batch sizes (CPU-PJRT budgets; LeNet is the e2e workhorse).
BATCH = {"lenet5": 64, "vgg16_cifar": 8, "mobilenet_cifar": 8}


def make_infer(mod):
    def infer(x, y, lvls, threshs, *params):
        logits = mod.apply(list(params), x, lvls, threshs)
        loss = layers.cross_entropy(logits, y, mod.NUM_CLASSES)
        acc = layers.accuracy(logits, y)
        return (loss, acc)

    return infer


def make_train_step(mod):
    import jax

    def loss_fn(params, x, y, lvls, threshs):
        logits = mod.apply(params, x, lvls, threshs)
        loss = layers.cross_entropy(logits, y, mod.NUM_CLASSES)
        return loss, layers.accuracy(logits, y)

    def train_step(x, y, lvls, threshs, lr, *params):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            list(params), x, y, lvls, threshs
        )
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return (loss, acc, *new_params)

    return train_step


def example_args(name: str, train: bool):
    """ShapeDtypeStructs for AOT lowering."""
    import jax
    import jax.numpy as jnp

    mod = NETWORKS[name]
    b = BATCH[name]
    h, w, c = mod.INPUT_SHAPE
    x = jax.ShapeDtypeStruct((b, h, w, c), jnp.float32)
    y = jax.ShapeDtypeStruct((b,), jnp.int32)
    lvls = jax.ShapeDtypeStruct((mod.NUM_COMPUTE_LAYERS,), jnp.float32)
    threshs = jax.ShapeDtypeStruct((mod.NUM_COMPUTE_LAYERS,), jnp.float32)
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _n, s in mod.PARAM_SPECS]
    if train:
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        return (x, y, lvls, threshs, lr, *params)
    return (x, y, lvls, threshs, *params)


def meta(name: str) -> dict:
    """Artifact metadata the Rust runtime reads."""
    mod = NETWORKS[name]
    return {
        "name": name,
        "batch": BATCH[name],
        "input_shape": list(mod.INPUT_SHAPE),
        "num_classes": mod.NUM_CLASSES,
        "num_compute_layers": mod.NUM_COMPUTE_LAYERS,
        "params": [
            {"name": n, "shape": list(s)} for n, s in mod.PARAM_SPECS
        ],
    }
